"""Batched serving example: wave-scheduled continuous batching over a reduced
qwen3-8b — prefill once, decode in lockstep slots, EOS early-exit.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.config.registry import get_arch
from repro.models.model import ModelOptions, build_model
from repro.runtime.server import BatchServer, Request


def main() -> None:
    cfg = get_arch("qwen3-8b").reduced()
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, params, slots=4, max_len=128)

    rng = np.random.default_rng(7)
    n_req = 10
    for i in range(n_req):
        server.submit(Request(
            prompt=rng.integers(1, cfg.vocab_size, 8 + i).tolist(),
            max_new_tokens=12))

    t0 = time.time()
    served = server.run_all()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in served)
    for i, r in enumerate(served):
        print(f"req{i:02d} prompt_len={len(r.prompt):2d} -> "
              f"{len(r.output)} new tokens: {r.output}")
    print(f"\n{len(served)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s on 1 CPU core, reduced config)")


if __name__ == "__main__":
    main()
