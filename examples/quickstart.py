"""Quickstart: the HDOT idea in 60 lines.

1. ONE partition scheme (`decompose_grid`) reused at process level (mesh
   shards) and task level (subdomains) — paper §3.2.
2. A stencil solve under the two schedules: two_phase (the MPI+OpenMP
   baseline: exchange, barrier, compute) vs hdot (boundary/interior split,
   comm rides the dataflow) — paper Code 2 vs Code 4.
3. The same discipline on an LM: per-bucket gradient reductions.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core.domain import Domain, decompose_grid
from repro.core.stencil import heat2d_init, heat2d_solve
from repro.launch.mesh import make_mesh

# --- 1. hierarchical over-decomposition --------------------------------------
print("== 1. one scheme, two levels ==")
boxes = decompose_grid((128, 128), (4, 1))          # process level (4 "ranks")
print(f"process level: {len(boxes)} domains, shapes {sorted({b.shape for b in boxes})}")
dom = Domain.for_rank((128, 128), (4, 1), rank=1)
subs = dom.over_decompose((4, 1))                   # task level, SAME scheme
n_boundary = sum(1 for s in subs if s.is_boundary(dim=0))
print(f"task level:    {len(subs)} subdomains per domain, "
      f"{n_boundary} of them boundary (own a comm task)")

# --- 2. two schedules, identical numerics -------------------------------------
print("\n== 2. Heat2D: two_phase vs hdot ==")
mesh = make_mesh((jax.device_count(),), ("data",))
u0 = heat2d_init(128, 128)
u_tp, res_tp = heat2d_solve(u0, mesh, ("data",), iters=50, mode="two_phase")
u_hd, res_hd = heat2d_solve(u0, mesh, ("data",), iters=50, mode="hdot")
print(f"residual after 50 sweeps: two_phase={float(res_tp[-1]):.3e} "
      f"hdot={float(res_hd[-1]):.3e}")
print(f"fields identical: {np.allclose(np.asarray(u_tp), np.asarray(u_hd))}")

# --- 3. the same idea on an LM step -------------------------------------------
print("\n== 3. gradient domain over-decomposition ==")
from repro.core.overlap import make_buckets
from repro.config.registry import get_arch
from repro.models.model import ModelOptions, build_model

cfg = get_arch("internlm2-1.8b").reduced()
model = build_model(cfg, ModelOptions(attn_impl="dense"))
params = model.init(jax.random.PRNGKey(0))
buckets = make_buckets(params, 8)
sizes = [sum(int(l.size) for _, l in b) for b in buckets]
print(f"{len(jax.tree.leaves(params))} gradient leaves -> {len(buckets)} "
      f"size-balanced buckets (subdomains): {sizes}")
print("each bucket is an independent all-reduce the scheduler can overlap "
      "with backward compute — no two-phase barrier.")
