"""End-to-end driver: train a ~100M-param decoder LM with the full stack —
synthetic-but-learnable data, AdamW, microbatch accumulation, async atomic
checkpoints, exact restart. (The paper-kind deliverable: train a ~100M model
for a few hundred steps.)

Run:  PYTHONPATH=src python examples/train_lm.py --preset 20m --steps 200
      PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import math

from repro.config.base import ModelConfig, ParallelConfig, RunConfig, TrainConfig

PRESETS = {
    # ~101M params: 2*16k*640 emb + 10*(4*640^2 + 3*640*2560) = 101.4M
    "100m": dict(d_model=640, num_layers=10, num_heads=10, num_kv_heads=5,
                 d_ff=2560, vocab_size=16000, seq_len=256, global_batch=8),
    # ~21M: CI-sized; same family, runs 200 steps in ~10 min on 1 CPU core
    "20m": dict(d_model=320, num_layers=6, num_heads=8, num_kv_heads=4,
                d_ff=1280, vocab_size=8000, seq_len=128, global_batch=8),
    "2m": dict(d_model=128, num_layers=2, num_heads=4, num_kv_heads=2,
               d_ff=512, vocab_size=1024, seq_len=64, global_batch=8),
}


def build_run(preset: str, steps: int, ckpt_dir: str, accum: int) -> RunConfig:
    p = dict(PRESETS[preset])
    seq_len = p.pop("seq_len")
    global_batch = p.pop("global_batch")
    cfg = ModelConfig(name=f"lm-{preset}", family="dense", qk_norm=True, **p)
    return RunConfig(
        model=cfg,
        parallel=ParallelConfig(remat="none", accum_steps=accum),
        train=TrainConfig(global_batch=global_batch, seq_len=seq_len,
                          # lr swept on the 2m preset: 3e-4 barely moves at
                          # this scale/batch, 2e-3 drops ~1.9 nats in 120 steps
                          lr=2e-3, warmup_steps=max(10, steps // 20),
                          total_steps=steps,
                          checkpoint_every=max(10, steps // 10),
                          checkpoint_dir=ckpt_dir, seed=0),
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=sorted(PRESETS), default="100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    from repro.runtime.trainer import Trainer

    run = build_run(args.preset, args.steps, args.ckpt_dir, args.accum)
    n_params = run.model.num_params()
    print(f"[train_lm] {run.model.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {run.train.global_batch} x "
          f"seq {run.train.seq_len}")
    trainer = Trainer(run)
    if args.resume:
        trainer.restore_if_available()
        print(f"[train_lm] resumed at step {trainer.step}")
    result = trainer.train(args.steps - trainer.step)
    losses = [m["loss"] for m in trainer.metrics_log]
    k = max(1, len(losses) // 10)
    print(f"[train_lm] loss first-{k}-avg={sum(losses[:k])/k:.4f} "
          f"last-{k}-avg={sum(losses[-k:])/k:.4f}")
    print(f"[train_lm] {result['seconds']:.1f}s total, "
          f"{result['seconds']/max(1,result['steps']):.2f}s/step")
    # The improvement assert is only meaningful on the POST-WARMUP trend:
    # inside LR warmup the step size is a fraction of the target lr, so the
    # loss barely moves and the first-vs-last comparison is noise (runs of
    # --steps 4 with warmup 10 failed on it at baseline). Short runs get a
    # sanity bound instead: the loss must stay finite and near the
    # uniform-prediction level ln(vocab).
    warm = run.train.warmup_steps
    assert all(math.isfinite(l) for l in losses), "loss diverged"
    if len(losses) > warm + 2 * k:
        post = losses[warm:]
        assert (sum(post[-k:]) / k
                < sum(post[:k]) / k), "post-warmup loss did not improve"
        print("[train_lm] OK — post-warmup loss decreased")
    else:
        bound = math.log(run.model.vocab_size) + 1.5
        assert losses[-1] < bound, f"loss {losses[-1]:.3f} above {bound:.3f}"
        print(f"[train_lm] OK — run inside warmup ({len(losses)} <= "
              f"{warm} + 2*{k} steps); loss sane (< ln(vocab)+1.5)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
