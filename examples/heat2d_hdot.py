"""Paper §4.1 walkthrough: Heat2D with hierarchical over-decomposition.

Shows the solver converging, the two schedules agreeing bit-for-bit, and the
Pallas tile kernel (interpret mode on CPU) matching the jnp oracle — the
three layers of the HDOT stack: mesh shards -> subdomain schedule -> VMEM tile.

Run:  PYTHONPATH=src python examples/heat2d_hdot.py
"""
import jax
import numpy as np

from repro.core.domain import halo_fraction
from repro.core.stencil import heat2d_init, heat2d_solve
from repro.kernels.heat2d import ops as heat_ops
from repro.launch.mesh import make_mesh


def ascii_field(u: np.ndarray, width: int = 48) -> str:
    chars = " .:-=+*#%@"
    step = max(1, u.shape[0] // 16), max(1, u.shape[1] // width)
    rows = []
    lo, hi = float(u.min()), float(u.max()) + 1e-9
    for i in range(0, u.shape[0], step[0]):
        row = ""
        for j in range(0, u.shape[1], step[1]):
            v = (float(u[i, j]) - lo) / (hi - lo)
            row += chars[min(int(v * len(chars)), len(chars) - 1)]
        rows.append(row)
    return "\n".join(rows)


def main() -> None:
    # paper Table 1: the memory cost of NOT sharing memory
    print("paper Table 1 — halo share of allocated memory (128x128, 1-D):")
    for ranks in (2, 4, 8, 16, 32):
        _, _, frac = halo_fraction((128, 128), (ranks, 1))
        print(f"  {ranks:3d} ranks: {100*frac:5.1f}%")

    mesh = make_mesh((jax.device_count(),), ("data",))
    u0 = heat2d_init(128, 128)
    print("\ninitial field:")
    print(ascii_field(np.asarray(u0)))

    for iters in (25, 100):
        u_hd, res = heat2d_solve(u0, mesh, ("data",), iters, mode="hdot")
        print(f"\nafter {iters} HDOT sweeps (residual {float(res[-1]):.3e}):")
        print(ascii_field(np.asarray(u_hd)))

    u_tp, _ = heat2d_solve(u0, mesh, ("data",), 100, mode="two_phase")
    print(f"\ntwo_phase == hdot: "
          f"{np.allclose(np.asarray(u_tp), np.asarray(u_hd), atol=1e-6)}")

    # kernel layer: blocked red-black GS tile (TPU target, interpret on CPU)
    u = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    got = heat_ops.heat2d_sweep(u, tile=(128, 128), impl="pallas",
                                interpret=True)
    want = heat_ops.heat2d_sweep(u, tile=(128, 128), impl="ref")
    print(f"pallas tile kernel == jnp oracle: "
          f"{np.allclose(np.asarray(got), np.asarray(want), atol=1e-6)}")


if __name__ == "__main__":
    main()
