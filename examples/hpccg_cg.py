"""Paper §4.3: HPCCG — taskified conjugate gradient on the 27-point operator.

The paper's Code 10/11: ddot becomes per-subdomain reduction partials + one
allreduce task; sparsemv carries the halo exchange. Both schedules converge
identically; the hdot schedule frees the z-halo ppermute to overlap the
in-plane stencil work.

Run:  PYTHONPATH=src python examples/hpccg_cg.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stencil import _stencil27_matvec, hpccg_solve
from repro.launch.mesh import make_mesh


def main() -> None:
    mesh = make_mesh((jax.device_count(),), ("data",))
    n = 24
    b = jax.random.normal(jax.random.PRNGKey(0), (n, n, n), jnp.float32)

    for mode in ("two_phase", "hdot"):
        x, hist = hpccg_solve(b, mesh, ("data",), iters=40, mode=mode)
        h = np.asarray(hist)
        print(f"{mode:10s}: ||r|| {h[0]:.3e} -> {h[-1]:.3e} "
              f"({h[0]/h[-1]:.1e}x) in 40 iters")

    # verify the solution actually solves the system
    Ax = _stencil27_matvec(x, None, "hdot")
    rel = float(jnp.linalg.norm(Ax - b) / jnp.linalg.norm(b))
    print(f"relative residual ||Ax-b||/||b|| = {rel:.2e}")
    print("convergence is schedule-invariant; the schedules differ only in "
          "WHERE the collectives sit in the dataflow (see benchmarks/hpccg).")


if __name__ == "__main__":
    main()
