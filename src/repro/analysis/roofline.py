"""Three-term roofline model for TPU v5e (DESIGN.md §6).

  t_comp = HLO_FLOPs_per_chip / peak_FLOPs
  t_mem  = HLO_bytes_per_chip / HBM_bw
  t_coll = collective_wire_bytes_per_chip / ICI_link_bw

SPMD ``cost_analysis()`` / HLO text are per-device, so no further chip
normalization is applied. The achievable step time under perfect overlap is
``max`` of the three terms (the HDOT ideal); the paper's two-phase baseline is
``t_comp + t_coll`` (serial comm phases). Roofline fraction compares useful
model FLOPs against the overlapped bound.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional


@dataclass(frozen=True)
class HW:
    """TPU v5e per-chip constants (brief-specified)."""

    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # B/s
    ici_bw: float = 50e9              # B/s per link (one direction)
    hbm_bytes: float = 16e9


V5E = HW()


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float                  # per chip
    hlo_bytes: float                  # per chip
    coll_bytes: float                 # per chip (ring-model wire)
    model_flops: float                # 6*N(_active)*D, GLOBAL
    hw: HW = field(default_factory=lambda: V5E)
    arg_bytes: float = 0.0            # per chip, from memory_analysis
    temp_bytes: float = 0.0
    out_bytes: float = 0.0
    notes: str = ""

    # ------------------------------------------------------------------ terms
    @property
    def t_comp(self) -> float:
        return self.hlo_flops / self.hw.peak_flops

    @property
    def t_mem(self) -> float:
        return self.hlo_bytes / self.hw.hbm_bw

    @property
    def t_coll(self) -> float:
        return self.coll_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_comp, "memory": self.t_mem,
                 "collective": self.t_coll}
        return max(terms, key=terms.get)  # type: ignore[arg-type]

    @property
    def t_step_overlapped(self) -> float:
        """HDOT bound: perfect overlap of the three engines."""
        return max(self.t_comp, self.t_mem, self.t_coll)

    @property
    def t_step_two_phase(self) -> float:
        """Paper-baseline bound: comm serializes with compute."""
        return max(self.t_comp, self.t_mem) + self.t_coll

    @property
    def t_useful(self) -> float:
        """Time the chips would need for the useful model FLOPs alone."""
        return (self.model_flops / self.chips) / self.hw.peak_flops

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — catches remat/redundancy waste."""
        total_hlo = self.hlo_flops * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOPs MFU bound at the overlapped step time."""
        t = self.t_step_overlapped
        return self.t_useful / t if t else 0.0

    @property
    def mem_fit(self) -> bool:
        resident = self.arg_bytes + self.out_bytes + self.temp_bytes
        return resident <= self.hw.hbm_bytes

    # ---------------------------------------------------------------- display
    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_comp_s": self.t_comp, "t_mem_s": self.t_mem,
            "t_coll_s": self.t_coll, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "hbm_resident_gb": (self.arg_bytes + self.out_bytes
                                + self.temp_bytes) / 1e9,
            "mem_fit": self.mem_fit,
            "notes": self.notes,
        }

    def __str__(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:10s} "
                f"comp={self.t_comp*1e3:9.2f}ms mem={self.t_mem*1e3:9.2f}ms "
                f"coll={self.t_coll*1e3:9.2f}ms dom={self.dominant:10s} "
                f"useful={self.useful_flops_ratio:6.3f} "
                f"roofline={self.roofline_fraction:6.3f}")


def roofline(arch: str, shape: str, mesh: str, chips: int,
             hlo_flops: float, hlo_bytes: float, coll_bytes: float,
             model_flops: float, hw: Optional[HW] = None,
             **mem) -> RooflineReport:
    return RooflineReport(arch=arch, shape=shape, mesh=mesh, chips=chips,
                          hlo_flops=hlo_flops, hlo_bytes=hlo_bytes,
                          coll_bytes=coll_bytes, model_flops=model_flops,
                          hw=hw or V5E, **mem)


def model_flops_for(num_params_active: int, tokens: int, kind: str,
                    backward: bool = True) -> float:
    """MODEL_FLOPS = 6*N*D for train (fwd 2ND + bwd 4ND), 2*N*D for inference."""
    if kind == "train":
        return 6.0 * num_params_active * tokens
    return 2.0 * num_params_active * tokens
