"""Canonical lowerings for the HLO schedule linter.

Each target lowers one of the repo's jitted programs — the stencil solvers,
the raw halo scans, the explicit grad-sync schedules, the lm train steps
(replicated-HDOT and FSDP) — to PRE-optimization HLO and pairs it with a
:class:`LintContext` whose expectations are **derived from the same code the
runtime uses** (``make_buckets`` / ``fsdp_layout`` element counts, the
schedule's pair-count arithmetic), so the linter cannot drift from the
implementation.

Lowering is abstract throughout (ShapeDtypeStructs, no parameters
materialized) — a full lm FSDP target lints in seconds on 8 fake CPU
devices (set ``--xla_force_host_platform_device_count`` before jax imports;
the CLI in ``hlo_lint`` does this).

``BROKEN`` holds the mutation fixtures: deliberately mis-scheduled variants
(unpeeled drain, tree bucket order, two-phase monolithic sync, lost
donation, double gather) that the test suite asserts DO trigger their rule.
They are buildable but excluded from ``all_targets()`` so CI lints only the
canonical set.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Tuple

from repro.analysis.rules import LintContext

# pair-count arithmetic per schedule (permute ops = 2 * pair-sets):
#   halo_scan / heat2d : one fwd+bwd pair per axis per step, drain peeled
#   rk3                : 3 stages/step, fill + peeled final stage -> 3*steps
#   hpccg              : one exchange chain per iter, fill + iters-1
PERMUTES_HALO = lambda axes, steps: 2 * axes * steps
PERMUTES_RK3 = lambda axes, steps: 2 * axes * 3 * steps
PERMUTES_HPCCG = lambda axes, iters: 2 * axes * iters
#   moe EP a2a_scan   : dispatch + combine per capacity slice (2Q) in the
#                       forward, and 2Q again in the backward (a2a is its
#                       own transpose)
A2AS_MOE = lambda chunks: 4 * chunks

_HLO_DTYPE = {"float32": "f32", "float64": "f64", "float16": "f16",
              "bfloat16": "bf16", "int32": "s32", "int64": "s64",
              "int8": "s8", "uint8": "u8", "uint32": "u32", "bool": "pred",
              "float8_e4m3fn": "f8e4m3fn", "float8_e5m2": "f8e5m2"}


def hlo_dtype(np_dtype) -> str:
    import numpy as np

    return _HLO_DTYPE.get(np.dtype(np_dtype).name, np.dtype(np_dtype).name)


@dataclass
class Target:
    name: str
    hlo_text: str
    ctx: LintContext


TARGETS: Dict[str, Callable[[], Target]] = {}
BROKEN: Dict[str, Callable[[], Target]] = {}


def _register(name: str, registry: Dict):
    def deco(fn):
        registry[name] = fn
        fn.__lint_name__ = name
        return fn
    return deco


def target(name: str):
    return _register(name, TARGETS)


def broken(name: str):
    return _register(name, BROKEN)


def all_targets() -> List[str]:
    return list(TARGETS)


def describe() -> List[Tuple[str, str]]:
    return [(n, (fn.__doc__ or "").strip().splitlines()[0])
            for n, fn in TARGETS.items()]


def build(name: str) -> Target:
    fn = TARGETS.get(name) or BROKEN.get(name)
    if fn is None:
        raise KeyError(f"unknown lint target {name!r}; known: "
                       f"{', '.join([*TARGETS, *BROKEN])}")
    return fn()


def _pre_opt_text(jitted, *specs) -> str:
    return jitted.lower(*specs).compiler_ir(dialect="hlo").as_hlo_text()


# ----------------------------------------------------------- raw halo scans
def _halo_jit(ndim: int, steps: int, peel: bool, donate: bool = True):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.halo import halo_scan_nd
    from repro.launch.mesh import make_grid_mesh, make_mesh

    donate_argnums = (0,) if donate else ()
    if ndim == 1:
        mesh = make_mesh((4,), ("data",))
        avg3 = lambda p: (p[:-2] + p[1:-1] + p[2:]) / 3.0
        f = jax.shard_map(
            lambda x: halo_scan_nd(x, avg3, (("data", 0),), 1, steps,
                                   periodic=True, subdomains=(4,), peel=peel,
                                   unroll=steps)[0],
            mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))
        spec = jax.ShapeDtypeStruct((16, 4), jnp.float32)
    elif ndim == 2:
        mesh = make_grid_mesh(2, 2)
        star = lambda p: (p[1:-1, 1:-1] + p[:-2, 1:-1] + p[2:, 1:-1]
                          + p[1:-1, :-2] + p[1:-1, 2:]) / 5.0
        f = jax.shard_map(
            lambda x: halo_scan_nd(x, star, (("rows", 0), ("cols", 1)), 1,
                                   steps, periodic=True, subdomains=(2, 2),
                                   peel=peel, unroll=steps)[0],
            mesh=mesh, in_specs=(P("rows", "cols"),),
            out_specs=P("rows", "cols"))
        spec = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    else:
        mesh = make_grid_mesh(2, 2, 2)
        axes = ("planes", "rows", "cols")
        star3 = lambda p: (p[1:-1, 1:-1, 1:-1] + p[:-2, 1:-1, 1:-1]
                           + p[2:, 1:-1, 1:-1] + p[1:-1, :-2, 1:-1]
                           + p[1:-1, 2:, 1:-1] + p[1:-1, 1:-1, :-2]
                           + p[1:-1, 1:-1, 2:]) / 7.0
        f = jax.shard_map(
            lambda x: halo_scan_nd(x, star3, tuple(zip(axes, (0, 1, 2))), 1,
                                   steps, periodic=True, peel=peel,
                                   unroll=steps)[0],
            mesh=mesh, in_specs=(P(*axes),), out_specs=P(*axes))
        spec = jax.ShapeDtypeStruct((8, 8, 8), jnp.float32)
    return jax.jit(f, donate_argnums=donate_argnums), spec


def _halo_target(name: str, ndim: int) -> Target:
    steps = 2
    jitted, spec = _halo_jit(ndim, steps, peel=True)
    ctx = LintContext(target=name,
                      expected_permute_total=PERMUTES_HALO(ndim, steps),
                      expect_donation=True)
    return Target(name, _pre_opt_text(jitted, spec), ctx)


@target("halo1d")
def _halo1d() -> Target:
    """halo_scan, 1-D ring of 4, steps=2 unrolled+peeled, donated input."""
    return _halo_target("halo1d", 1)


@target("halo2d")
def _halo2d() -> Target:
    """halo_scan_2d on a 2x2 mesh, steps=2 unrolled+peeled, donated input."""
    return _halo_target("halo2d", 2)


@target("halo3d")
def _halo3d() -> Target:
    """halo_scan_nd on a 2x2x2 mesh, steps=2 unrolled+peeled, donated."""
    return _halo_target("halo3d", 3)


# --------------------------------------------------------------- solvers
@target("heat2d_1d")
def _heat2d_1d() -> Target:
    """heat2d Jacobi sweeps, 1-D slab decomposition over 4 devices."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _heat2d_solver
    from repro.launch.mesh import make_mesh

    f = _heat2d_solver(make_mesh((4,), ("data",)), ("data",), 2, "hdot", 4)
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    return Target("heat2d_1d", txt,
                  LintContext(target="heat2d_1d",
                              expected_permute_total=PERMUTES_HALO(1, 2)))


@target("heat2d_2d")
def _heat2d_2d() -> Target:
    """heat2d with true 2-D (rows x cols) block decomposition on 2x2."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _heat2d_solver
    from repro.launch.mesh import make_grid_mesh

    f = _heat2d_solver(make_grid_mesh(2, 2), ("rows", "cols"), 2, "hdot",
                       (2, 2))
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    return Target("heat2d_2d", txt,
                  LintContext(target="heat2d_2d",
                              expected_permute_total=PERMUTES_HALO(2, 2)))


@target("heat2d_weighted")
def _heat2d_weighted() -> Target:
    """heat2d hdot with a measured-cost WEIGHTED (uneven) interior re-cut on
    a 2x2 mesh: the dynamic load-balancing lowering. The face partition — and
    thus the ppermute schedule — must be identical to the uniform cut (same
    pair count, zero exposed collectives); only the interior chunk grid is
    uneven (local 16x18 block, interior 14x16 cut (5,9) x (7,9))."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _heat2d_solver
    from repro.launch.mesh import make_grid_mesh

    f = _heat2d_solver(make_grid_mesh(2, 2), ("rows", "cols"), 2, "hdot",
                       (2, 2), ((5, 9), (7, 9)))
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((32, 36), jnp.float32))
    return Target("heat2d_weighted", txt,
                  LintContext(target="heat2d_weighted",
                              expected_permute_total=PERMUTES_HALO(2, 2)))


@target("rk3_1d")
def _rk3_1d() -> Target:
    """RK3 advection, z-slab decomposition over 4 devices, steps=2."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _rk3_solver
    from repro.launch.mesh import make_mesh

    # global dim 2 = 64 so the local shard keeps >= 16 cells (the pipelined
    # stage-carried path; smaller shards take the per-step fallback)
    f = _rk3_solver(make_mesh((4,), ("data",)), ("data",), 2, 0.01, "hdot")
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((12, 16, 64), jnp.float32))
    return Target("rk3_1d", txt,
                  LintContext(target="rk3_1d",
                              expected_permute_total=PERMUTES_RK3(1, 2)))


@target("rk3_2d")
def _rk3_2d() -> Target:
    """RK3 on a (y, z) 2x2 grid mesh, stage-carried halos on both axes."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _rk3_solver
    from repro.launch.mesh import make_grid_mesh

    f = _rk3_solver(make_grid_mesh(2, 2), ("rows", "cols"), 2, 0.01, "hdot")
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((12, 32, 32), jnp.float32))
    return Target("rk3_2d", txt,
                  LintContext(target="rk3_2d",
                              expected_permute_total=PERMUTES_RK3(2, 2)))


@target("hpccg_1d")
def _hpccg_1d() -> Target:
    """HPCCG CG iterations, 1-D decomposition over 4 devices, iters=2."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _hpccg_solver
    from repro.launch.mesh import make_mesh

    f = _hpccg_solver(make_mesh((4,), ("data",)), ("data",), 2, "hdot", 4)
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((12, 20, 20), jnp.float32))
    return Target("hpccg_1d", txt,
                  LintContext(target="hpccg_1d",
                              expected_permute_total=PERMUTES_HPCCG(1, 2)))


@target("hpccg_3d")
def _hpccg_3d() -> Target:
    """HPCCG on a 2x2x2 (planes x rows x cols) mesh, iters=2."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _hpccg_solver
    from repro.launch.mesh import make_grid_mesh

    f = _hpccg_solver(make_grid_mesh(2, 2, 2), ("planes", "rows", "cols"),
                      2, "hdot", 4)
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((12, 20, 20), jnp.float32))
    return Target("hpccg_3d", txt,
                  LintContext(target="hpccg_3d",
                              expected_permute_total=PERMUTES_HPCCG(3, 2)))


# ------------------------------------------------------------- grad sync
_SYNC_TREE_SIZES = {"embed": 11, "w1": 23, "w2": 37, "head": 53}
_SYNC_TREE_LAYERS = {"embed": 0, "w1": 1, "w2": 2, "head": 3}


def _grad_sync_jit(order: str, mode: str = "hdot"):
    import functools

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.overlap import grad_sync
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("data",))
    specs = {k: jax.ShapeDtypeStruct((n,), jnp.float32)
             for k, n in _SYNC_TREE_SIZES.items()}
    f = jax.jit(jax.shard_map(
        functools.partial(grad_sync, axes="data", mode=mode, num_buckets=4,
                          layers=_SYNC_TREE_LAYERS, order=order),
        mesh=mesh, in_specs=(P(),), out_specs=P()))
    return f, specs


def _grad_sync_expected(order: str) -> List[int]:
    """Per-leaf all-reduce element counts in emission order, from
    make_buckets itself. A bucket is one multi-operand ``lax.psum``, but the
    pre-opt HLO carries one all-reduce instruction per leaf (consecutive
    channel ids), so the lint-level expectation is the flattened sequence."""
    import numpy as np

    from repro.core.overlap import make_buckets

    tree = {k: np.zeros((n,), np.float32)
            for k, n in _SYNC_TREE_SIZES.items()}
    buckets = make_buckets(tree, 4, layers=_SYNC_TREE_LAYERS, order=order)
    return [leaf.size for b in buckets for _, leaf in b]


@target("grad_sync_1d")
def _grad_sync_1d() -> Target:
    """Explicit HDOT grad sync: per-bucket psums, reverse-topo emission."""
    f, specs = _grad_sync_jit("reverse_topo")
    expected = _grad_sync_expected("reverse_topo")
    ctx = LintContext(target="grad_sync_1d", expected_permute_total=0,
                      expected_ar_elements=expected,
                      wire_dtype_elements={
                          "f32": sum(_SYNC_TREE_SIZES.values())})
    return Target("grad_sync_1d", _pre_opt_text(f, specs), ctx)


# ------------------------------------------------------------ lm steps
def _lm_trainer(parallel, mesh_shape, axes):
    from repro.config.base import RunConfig, TrainConfig
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.runtime.trainer import Trainer

    cfg = get_arch("qwen3-8b").reduced()
    train = TrainConfig(global_batch=8, seq_len=32, warmup_steps=2,
                        total_steps=10, checkpoint_every=10**6,
                        checkpoint_dir="/tmp/repro_lint_ckpt")
    mesh = make_mesh(mesh_shape, axes)
    return Trainer(RunConfig(cfg, parallel, train), mesh=mesh), mesh


def _lm_specs(trainer):
    import jax

    from repro.optim import adamw_init

    pspec = trainer.model.abstract_params()
    ospec = jax.eval_shape(adamw_init, pspec)
    batch = trainer._augment_frontend(trainer.data.batch_at(0))
    bspec = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in batch.items()}
    return pspec, ospec, bspec


def _param_budget(pspec) -> Dict[str, int]:
    import jax
    import numpy as np

    budget: Dict[str, int] = {}
    for leaf in jax.tree.leaves(pspec):
        dt = hlo_dtype(leaf.dtype)
        budget[dt] = budget.get(dt, 0) + int(np.prod(leaf.shape))
    return budget


def _lm_hdot_target(name: str, mesh_shape, axes, overlap: str = "hdot"
                    ) -> Target:
    from repro.config.base import ParallelConfig

    par = ParallelConfig(param_shard=False, remat="none", overlap=overlap)
    trainer, _ = _lm_trainer(par, mesh_shape, axes)
    jitted = trainer._build_step()
    pspec, ospec, bspec = _lm_specs(trainer)
    ctx = LintContext(target=name, expected_permute_total=0,
                      wire_dtype_elements=_param_budget(pspec),
                      expect_donation=True)
    return Target(name, _pre_opt_text(jitted, pspec, ospec, bspec), ctx)


@target("lm_hdot_1d")
def _lm_hdot_1d() -> Target:
    """lm train step, explicit HDOT bucketed grad sync, 4-way DP."""
    return _lm_hdot_target("lm_hdot_1d", (4,), ("data",))


@target("lm_hdot_2d")
def _lm_hdot_2d() -> Target:
    """lm train step, HDOT grad sync over a 2-D (pod x data) DP mesh."""
    return _lm_hdot_target("lm_hdot_2d", (2, 2), ("pod", "data"))


@target("lm_fsdp_1d")
def _lm_fsdp_1d() -> Target:
    """lm FSDP (ZeRO-3) step: one RS + one AG per bucket, reverse emission."""
    import jax

    from repro.config.base import ParallelConfig
    from repro.launch.steps import fsdp_layout_for, make_fsdp_train_step
    from repro.optim import adamw_init

    par = ParallelConfig(param_shard=True, remat="none")
    trainer, mesh = _lm_trainer(par, (4,), ("data",))
    layout, _ = fsdp_layout_for(trainer.model, par, mesh)
    step_fn = make_fsdp_train_step(trainer.model, par, mesh,
                                   trainer.opt_cfg, layout=layout)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    n = layout.n_shards
    # global flat buffers (the step's shard_map splits them over the DP axes)
    pflat = {g.key: jax.ShapeDtypeStruct((g.padded,), g.dtype)
             for g in layout.groups}
    ospec = jax.eval_shape(adamw_init, pflat)
    _, _, bspec = _lm_specs(trainer)
    budget: Dict[str, int] = {}
    for g in layout.groups:
        dt = hlo_dtype(g.dtype)
        budget[dt] = budget.get(dt, 0) + g.padded // n
    ctx = LintContext(
        target="lm_fsdp_1d", expected_permute_total=0,
        expected_rs_elements=[g.padded // n for g in reversed(layout.groups)],
        expected_ag_elements=[g.padded for g in layout.groups],
        wire_dtype_elements=budget, expect_donation=True)
    return Target("lm_fsdp_1d", _pre_opt_text(jitted, pflat, ospec, bspec),
                  ctx)


def _lm_fsdp_streaming_pieces(streaming: bool):
    """Shared lowering for the streaming target and its gather-all mutation
    fixture: SAME per-layer layout, SAME model options; only the gather
    placement differs (inside each consuming layer's remat region vs a
    top-of-step gather-all)."""
    import jax

    from repro.config.base import ParallelConfig
    from repro.launch.steps import fsdp_layout_for, make_fsdp_train_step
    from repro.models.model import ModelOptions
    from repro.optim import adamw_init

    par = ParallelConfig(param_shard=True, fsdp_streaming=streaming,
                         scan_layers=False, remat="full",
                         bucket_order="layer")
    trainer, mesh = _lm_trainer(par, (4,), ("data",))
    trainer.options = ModelOptions(attn_impl="dense", scan_layers=False,
                                   remat="full", fused_xent=False)
    from repro.models.model import build_model

    trainer.model = build_model(trainer.run.model, trainer.options)
    layout, _ = fsdp_layout_for(trainer.model, par, mesh)
    step_fn = make_fsdp_train_step(trainer.model, par, mesh,
                                   trainer.opt_cfg, layout=layout)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    pflat = {g.key: jax.ShapeDtypeStruct((g.padded,), g.dtype)
             for g in layout.groups}
    ospec = jax.eval_shape(adamw_init, pflat)
    _, _, bspec = _lm_specs(trainer)
    n = layout.n_shards
    budget: Dict[str, int] = {}
    for g in layout.groups:
        dt = hlo_dtype(g.dtype)
        budget[dt] = budget.get(dt, 0) + g.padded // n
    return (jitted, pflat, ospec, bspec, layout, budget, par, trainer.model)


@target("lm_fsdp_streaming")
def _lm_fsdp_streaming() -> Target:
    """Streaming ZeRO-3 step: per-layer AG at point of use, regathered in the
    backward — pending-gather working set bounded by fsdp_working_set."""
    from repro.core.overlap import fsdp_stream

    jitted, pflat, ospec, bspec, layout, budget, par, model = (
        _lm_fsdp_streaming_pieces(True))
    n = layout.n_shards
    fwd = [g.padded for g in layout.groups]
    # the backward regathers every LAYER bucket in reverse layer order
    # (within a layer the remat retrace keeps forward order); the embed and
    # head buckets gather once — the take-backward never needs the table
    # primal and the head weight's residual spans only the forward/backward
    # boundary (models.model.train_loss_streamed)
    stream = fsdp_stream(layout, model.param_layers(), ("data",))
    layer_depths = [d for d in stream.depths
                    if d not in (0, max(stream.depths))]
    bwd = [g.padded for d in reversed(layer_depths)
           for g in stream.groups_at(d)]
    # RS emission = AD transpose order: head buckets first (their gathers
    # are the last forward consumers), then each layer's buckets as its
    # remat region replays (within-depth forward order), embed last
    rs = [g.padded // n
          for d in reversed(stream.depths) for g in stream.groups_at(d)]
    ctx = LintContext(
        target="lm_fsdp_streaming", expected_permute_total=0,
        expected_rs_elements=rs,
        expected_ag_elements=fwd + bwd,
        wire_dtype_elements=budget, expect_donation=True,
        extra={"fsdp_working_set": par.fsdp_working_set})
    return Target("lm_fsdp_streaming",
                  _pre_opt_text(jitted, pflat, ospec, bspec), ctx)


@broken("broken_gather_all_streaming")
def _broken_gather_all_streaming() -> Target:
    """Top-of-step gather-all on the SAME per-layer layout: every bucket's
    AG is pending at once, so only AG-ADJACENCY trips (the ctx expectations
    match this lowering's own emission: one AG per bucket forward-order, RS
    reversed)."""
    jitted, pflat, ospec, bspec, layout, budget, par, _ = (
        _lm_fsdp_streaming_pieces(False))
    n = layout.n_shards
    ctx = LintContext(
        target="broken_gather_all_streaming", expected_permute_total=0,
        expected_rs_elements=[g.padded // n for g in reversed(layout.groups)],
        expected_ag_elements=[g.padded for g in layout.groups],
        wire_dtype_elements=budget, expect_donation=True,
        extra={"fsdp_working_set": par.fsdp_working_set})
    return Target("broken_gather_all_streaming",
                  _pre_opt_text(jitted, pflat, ospec, bspec), ctx)


# ------------------------------------------------------------- moe EP a2a
def _lm_moe_grad_target(name: str, a2a_chunks: int) -> Target:
    """value_and_grad of the MoE EP layer (the same program
    ``tests/test_moe_ep.py`` checks numerically against the dense oracle) on
    a (1 data x 2 model) mesh: the model axis is non-trivial, so
    ``moe_apply`` takes the shard_map EP path and its all-to-alls are the
    only explicit collectives in the pre-opt HLO.

    Deliberately the LAYER grad, not the full lm train step: both the
    optimizer (``b1*m`` on every param leaf) and any vocab readout's
    label-side gradient seed (one-hot compare / take_along_axis scatter,
    B*S*V elements) are dataflow-independent of every trunk collective and
    would hand even the monolithic a2a a spurious NO-OVERLAP-WINDOW pass.
    The layer program keeps the window question honest: the only sized
    compute a forward dispatch/combine slice can be independent of is
    *another slice's* expert FFN, which is exactly the invariant the
    chunked schedule exists to create.

    ``scalar_elements`` is raised to 2048 so router bookkeeping (the aux
    one_hot is exactly B_loc*S_loc*K*E = 1024 elements here, the f_e/p_e
    pmeans 4) neither counts as an overlap window nor as sized traffic —
    only FFN-scale compute (>= 10240 elements/slice) can hide an a2a.
    """
    import jax
    import jax.numpy as jnp

    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.models.moe import moe_apply, moe_specs
    from repro.sharding.rules import use_sharding

    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    mesh = make_mesh((1, 2), ("data", "model"))

    def loss(p, x):
        y, aux = moe_apply(p, x, cfg, a2a_chunks=a2a_chunks)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux

    # grads w.r.t. params AND activations: in the full lm, d_x flows to the
    # previous layer through the transposed dispatch a2a — dropping it would
    # silently halve the backward a2a count
    jitted = jax.jit(jax.value_and_grad(loss, argnums=(0, 1)))
    pspec = {k: jax.ShapeDtypeStruct(s.shape, s.dtype)
             for k, s in moe_specs(cfg).items()}
    xspec = jax.ShapeDtypeStruct((8, 32, cfg.d_model), jnp.bfloat16)
    # the EP path is selected at trace time from current_context()
    with use_sharding(mesh):
        txt = _pre_opt_text(jitted, pspec, xspec)
    ctx = LintContext(target=name, expected_permute_total=0,
                      expected_a2a_total=A2AS_MOE(a2a_chunks),
                      scalar_elements=2048)
    return Target(name, txt, ctx)


@target("lm_moe_ep")
def _lm_moe_ep() -> Target:
    """MoE EP grads, a2a_scan chunked (Q=2): every a2a slice overlaps FFN."""
    return _lm_moe_grad_target("lm_moe_ep", 2)


def _decode_tp_target(name: str, mode: str) -> Target:
    """One TP-sharded continuous-batching decode step (models.decode_tp —
    the `BatchServer(decode_step_fn=...)` cell) on a (1 data x 2 model)
    mesh: 4L+1 collective-matmul rings (fused QKV ag, wo rs, fused gate|up
    ag, down rs per layer, plus the unembed ag), per-slot ring caches
    donated.

    `scalar_elements` is raised to 128 so the per-slot bookkeeping — cache
    `pos` compares / causal masks (slots*w = 128 elements here) and the rope
    angle tables ((slots, 1, hd/2) = 128) — neither counts as an overlap
    window nor as sized traffic; only ring-piece-scale matmul output
    (>= 256 elements) can hide a ppermute, which is exactly the chunk
    compute the hdot schedule creates. Cache writes are per-row
    dynamic-update-slices (NOT scatters) for the same reason — assembling a
    block is not compute (analysis/hlo_ir.COMPUTE_OPS), so the two-phase
    fixture cannot borrow an overlap window from its own cache updates.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.models.decode_tp import build_decode_step, expected_permute_total
    from repro.models.model import ModelOptions, build_model
    from repro.runtime.server import make_slot_caches

    cfg = get_arch("qwen3-8b").reduced()     # dense GQA + qk-norm
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    mesh = make_mesh((1, 2), ("data", "model"))
    slots, max_len = 8, 16
    jitted = jax.jit(build_decode_step(model, mesh, mode=mode),
                     donate_argnums=(2,))
    pspec = model.abstract_params()
    cspec = jax.eval_shape(
        functools.partial(make_slot_caches, model, slots, max_len))
    tok = jax.ShapeDtypeStruct((slots, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((slots,), jnp.int32)
    txt = _pre_opt_text(jitted, pspec, tok, cspec, pos)
    expected = (expected_permute_total(cfg, slots, 1, 2)
                if mode == "hdot" else 0)
    ctx = LintContext(target=name, expected_permute_total=expected,
                      max_exposed_collectives=0, expect_donation=True,
                      scalar_elements=128)
    return Target(name, txt, ctx)


@target("lm_decode_tp")
def _lm_decode_tp() -> Target:
    """TP continuous-decode step: (4L+1) hdot rings, zero exposed permutes."""
    return _decode_tp_target("lm_decode_tp", "hdot")


# ------------------------------------------------- mutation fixtures
@broken("broken_unpeeled_halo1d")
def _broken_unpeeled() -> Target:
    """PR-3 regression: unpeeled drain — dead exchange + wrong pair count."""
    steps = 2
    jitted, spec = _halo_jit(1, steps, peel=False)
    ctx = LintContext(target="broken_unpeeled_halo1d",
                      expected_permute_total=PERMUTES_HALO(1, steps),
                      expect_donation=True)
    return Target("broken_unpeeled_halo1d", _pre_opt_text(jitted, spec), ctx)


@broken("broken_no_donate_halo1d")
def _broken_no_donate() -> Target:
    """Donation dropped from the canonical halo jit."""
    jitted, spec = _halo_jit(1, 2, peel=True, donate=False)
    ctx = LintContext(target="broken_no_donate_halo1d",
                      expected_permute_total=PERMUTES_HALO(1, 2),
                      expect_donation=True)
    return Target("broken_no_donate_halo1d", _pre_opt_text(jitted, spec), ctx)


@broken("broken_tree_grad_sync")
def _broken_tree_order() -> Target:
    """Buckets emitted shallowest-first (order='tree') — wrong emission."""
    f, specs = _grad_sync_jit("tree")
    ctx = LintContext(target="broken_tree_grad_sync",
                      expected_ar_elements=_grad_sync_expected("reverse_topo"))
    return Target("broken_tree_grad_sync", _pre_opt_text(f, specs), ctx)


@broken("broken_two_phase_grad_sync")
def _broken_two_phase_sync() -> Target:
    """Monolithic two-phase psum of a mixed-dtype tree: the concat upcasts
    bf16 grads to f32 — full-width wire traffic (WIRE-WIDEN)."""
    import functools

    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.overlap import grad_sync
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("data",))
    specs = {"wq": jax.ShapeDtypeStruct((64, 8), jnp.bfloat16),
             "norm": jax.ShapeDtypeStruct((64,), jnp.float32)}
    f = jax.jit(jax.shard_map(
        functools.partial(grad_sync, axes="data", mode="two_phase"),
        mesh=mesh, in_specs=(P(),), out_specs=P()))
    ctx = LintContext(target="broken_two_phase_grad_sync",
                      wire_dtype_elements={"bf16": 64 * 8, "f32": 64})
    return Target("broken_two_phase_grad_sync", _pre_opt_text(f, specs), ctx)


@broken("broken_two_phase_heat2d")
def _broken_two_phase_heat2d() -> Target:
    """two_phase heat2d: exchange -> barrier -> compute, nothing overlaps."""
    import jax
    import jax.numpy as jnp

    from repro.core.stencil import _heat2d_solver
    from repro.launch.mesh import make_mesh

    f = _heat2d_solver(make_mesh((4,), ("data",)), ("data",), 2, "two_phase",
                       4)
    txt = _pre_opt_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    return Target("broken_two_phase_heat2d", txt,
                  LintContext(target="broken_two_phase_heat2d"))


@broken("broken_two_phase_decode_tp")
def _broken_two_phase_decode_tp() -> Target:
    """Two-phase TP decode: serial all_gather / psum_scatter walls around
    every projection matmul — GSPMD's schedule. Every sized op is an
    ancestor or descendant of the collective next to it (the per-row cache
    DUS writes don't count as compute), so NO-OVERLAP-WINDOW fires on each
    wall; the pair count (0 permutes) stays green so the failure is
    attributed to the schedule shape, not a miscount."""
    return _decode_tp_target("broken_two_phase_decode_tp", "two_phase")


@broken("broken_monolithic_a2a_moe")
def _broken_monolithic_a2a() -> Target:
    """Monolithic MoE a2a (Q=1): dispatch/combine with zero overlap window.

    The lint context still expects the monolithic pair count (4 a2as: the
    un-chunked fwd+bwd dispatch/combine), so PAIR-COUNT stays green and the
    failure is attributed to the schedule shape: NO-OVERLAP-WINDOW fires
    because every sized op in the module is an ancestor or descendant of
    the bulk a2as — nothing can hide them."""
    return _lm_moe_grad_target("broken_monolithic_a2a_moe", 1)


@broken("broken_double_gather_fsdp")
def _broken_double_gather() -> Target:
    """fsdp_all_gather called twice per step: two AGs per bucket buffer."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.core.overlap import fsdp_all_gather, fsdp_layout
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4,), ("data",))
    tree = {"wq": jax.ShapeDtypeStruct((64, 8), jnp.float32),
            "wk": jax.ShapeDtypeStruct((32, 8), jnp.float32)}
    layout = fsdp_layout(tree, 4, num_buckets=2)

    def local(flat):
        a = fsdp_all_gather(flat, layout, ("data",))
        b = fsdp_all_gather(flat, layout, ("data",))
        return sum(jnp.sum(x) + jnp.sum(y)
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    specs = {g.key: jax.ShapeDtypeStruct((g.padded,), g.dtype)
             for g in layout.groups}
    f = jax.jit(jax.shard_map(local, mesh=mesh,
                              in_specs=(P("data"),), out_specs=P(),
                              check_vma=False))
    ctx = LintContext(
        target="broken_double_gather_fsdp",
        expected_ag_elements=[g.padded for g in layout.groups])
    return Target("broken_double_gather_fsdp", _pre_opt_text(f, specs), ctx)
