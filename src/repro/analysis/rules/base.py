"""Finding / context / rule base types for the HLO schedule linter."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.hlo_ir import HloInstruction, HloModule
from repro.analysis.memtraffic import collective_wire_bytes


class Severity:
    ERROR = "error"      # schedule invariant broken — CI fails
    WARNING = "warning"  # suspicious but not provably wrong
    INFO = "info"        # annotation only (e.g. wire-bytes report)

    ORDER = {ERROR: 0, WARNING: 1, INFO: 2}


@dataclass
class Finding:
    """One structured lint finding: which rule, where, what, how to fix."""
    rule: str
    severity: str
    message: str
    fix_hint: str
    op: str = ""                 # instruction name, e.g. collective-permute.24
    computation: str = ""
    line: int = 0                # 1-based line in the linted HLO text
    wire_bytes: Optional[float] = None   # memtraffic ring-model annotation
    snippet: str = ""

    def to_dict(self) -> dict:
        d = {
            "rule": self.rule, "severity": self.severity,
            "message": self.message, "fix_hint": self.fix_hint,
            "op": self.op, "computation": self.computation, "line": self.line,
        }
        if self.wire_bytes is not None:
            d["wire_bytes"] = round(self.wire_bytes, 1)
        if self.snippet:
            d["snippet"] = self.snippet
        return d

    def __str__(self) -> str:
        loc = f"{self.computation}/{self.op}" if self.op else "<module>"
        wire = (f" [{self.wire_bytes / 1e3:.1f} kB wire]"
                if self.wire_bytes is not None else "")
        return (f"{self.severity.upper():7s} {self.rule:18s} {loc}"
                f" (line {self.line}){wire}\n"
                f"        {self.message}\n        fix: {self.fix_hint}")


@dataclass
class LintContext:
    """What the linted program is *supposed* to look like.

    Populated by the canonical-target factory (``lint_targets.py``) from the
    same schedule code the runtime uses — ``make_buckets`` / ``fsdp_layout``
    for bucket expectations, mesh/steps for pair counts — so lint
    expectations can never drift from the implementation.
    """
    target: str = ""
    # PAIR-COUNT: expected collective-permutes per mesh axis (peeled HDOT
    # schedule: 2 pairs/axis/step minus the peeled drain => 2*axes*steps).
    expected_permutes: Optional[Dict[str, int]] = None
    expected_permute_total: Optional[int] = None
    # PAIR-COUNT: expected all-to-alls (MoE EP dispatch+combine — 2Q per
    # forward and 2Q per backward MoE layer lowering; a2a is its own
    # transpose so there is no fwd/bwd ring balance to check).
    expected_a2a_total: Optional[int] = None
    # BUCKET-ORDER / ONE-RS-ONE-AG: per-(bucket x dtype) flat-buffer element
    # counts in *emission* order, from FsdpLayout / make_buckets.
    expected_rs_elements: Optional[List[int]] = None
    expected_ag_elements: Optional[List[int]] = None
    expected_ar_elements: Optional[List[int]] = None
    # WIRE-WIDEN: param-spec element budget per wire dtype; any reduction
    # collective moving more elements of dtype d than budget[d] (plus slack
    # for bucket padding) is carrying upcast gradients.
    wire_dtype_elements: Optional[Dict[str, int]] = None
    wire_pad_slack: int = 0
    # NO-OVERLAP-WINDOW: how many collectives are *allowed* zero overlap
    # (the pipeline-fill exchange before the first interior chunk).
    max_exposed_collectives: int = 0
    # DONATION-LOST: the canonical jit wraps state with donate_argnums.
    expect_donation: bool = False
    # collectives with <= this many elements are bookkeeping (loss pmean,
    # grad-norm scalars), skipped by traffic-oriented rules.
    scalar_elements: int = 8
    extra: Dict[str, object] = field(default_factory=dict)


def annotate_wire_bytes(instr: HloInstruction) -> Optional[float]:
    """memtraffic ring-model wire bytes for a collective instruction."""
    kind = instr.collective_kind
    if kind is None:
        return None
    return collective_wire_bytes(kind, instr.result_bytes(),
                                 instr.replica_group_size)


class Rule:
    """Base class: subclasses set id/severity/fix_hint and implement check."""
    id: str = ""
    severity: str = Severity.ERROR
    fix_hint: str = ""

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    def finding(self, message: str, *, comp: str = "", op: str = "",
                line: int = 0, wire_bytes: Optional[float] = None,
                snippet: str = "", fix_hint: str = "",
                severity: str = "") -> Finding:
        return Finding(rule=self.id, severity=severity or self.severity,
                       message=message, fix_hint=fix_hint or self.fix_hint,
                       op=op, computation=comp, line=line,
                       wire_bytes=wire_bytes, snippet=snippet)

    def op_finding(self, message: str, comp, instr: HloInstruction,
                   **kw) -> Finding:
        return self.finding(message, comp=comp.name, op=instr.name,
                            line=instr.line_no,
                            wire_bytes=annotate_wire_bytes(instr),
                            snippet=instr.raw[:160], **kw)


def sized_collectives(module: HloModule, kinds: Sequence[str],
                      ctx: LintContext
                      ) -> List[Tuple[object, HloInstruction]]:
    """Module collectives of the given kinds, scalar bookkeeping skipped."""
    return [(c, i) for c, i in module.collectives(kinds)
            if i.elements() > ctx.scalar_elements]
