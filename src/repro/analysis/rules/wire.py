"""WIRE-WIDEN: gradients crossing the wire wider than the param spec.

XLA upcasts bf16 accumulation to f32, and a naive grad sync (two_phase's
single concatenated psum) inherits that width: every bf16 gradient crosses
the interconnect as f32 — 2x the bytes for zero fidelity the optimizer can
use (it re-rounds to the param dtype on update). The HDOT per-dtype buckets
keep bf16 grads on a bf16 wire; ``optim/compression.py`` provides the
sanctioned narrowing path (bf16 / fp8 wire codecs with error-feedback) when
even that is too wide.

The rule compares, per wire dtype, the total elements moved by reduction
collectives (all-reduce / reduce-scatter, the grad-sync ops) against the
param spec's element budget for that dtype. Elements of a dtype the spec
does not contain — beyond padding slack — are upcast traffic.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List

from repro.analysis.hlo_ir import DTYPE_BYTES, HloModule
from repro.analysis.rules.base import (Finding, LintContext, Rule,
                                       annotate_wire_bytes,
                                       sized_collectives)


class WireWidenRule(Rule):
    """Reduction collectives moving more elements of a dtype than the param
    spec budgets for it are carrying upcast gradients (see module docstring:
    the two_phase concatenated psum inherits the f32 accumulator width).
    """
    id = "WIRE-WIDEN"
    fix_hint = ("sync grads per dtype (HDOT buckets keep bf16 grads on a "
                "bf16 wire); for narrower transport use the error-feedback "
                "wire codecs in optim/compression.py (bf16/fp8/int8)")

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        budget = ctx.wire_dtype_elements
        if budget is None:
            return []
        moved: Dict[str, int] = defaultdict(int)
        anchors = {}
        wire: Dict[str, float] = defaultdict(float)
        for comp, instr in sized_collectives(
                module, ["all-reduce", "reduce-scatter"], ctx):
            for part, (dt, _) in enumerate(instr.shapes):
                n = instr.elements(part)
                moved[dt] += n
                wire[dt] += (annotate_wire_bytes(instr) or 0.0)
                prev = anchors.get(dt)
                if prev is None or n > prev[1].elements():
                    anchors[dt] = (comp, instr)
        out: List[Finding] = []
        for dt, n in sorted(moved.items()):
            allowed = budget.get(dt, 0) + ctx.wire_pad_slack
            if n <= allowed:
                continue
            comp, instr = anchors[dt]
            widths = {d: DTYPE_BYTES.get(d, 0) for d in budget}
            narrower = [d for d, w in widths.items()
                        if w < DTYPE_BYTES.get(dt, 0) and budget[d] > 0]
            hint_dt = (f" (param spec holds {sorted(budget.items())}; "
                       f"likely upcast from {'/'.join(sorted(narrower))})"
                       if narrower else "")
            out.append(self.op_finding(
                f"reduction collectives move {n} {dt} elements but the "
                f"param spec budgets {allowed} — gradients are crossing "
                f"the wire widened{hint_dt}", comp, instr,
                severity=self.severity))
        return out
