"""Grad-sync bucket rules: BUCKET-ORDER, ONE-RS-ONE-AG, DONATION-LOST.

Expectations come from the *same* code the runtime uses — ``make_buckets`` /
``fsdp_layout`` element counts, fed in through :class:`LintContext` — so the
lint can never drift from the implementation. The rules then check the
lowered module against them:

* exactly one reduce-scatter and one all-gather per (bucket x dtype) flat
  buffer (no retrace duplicated a collective, no buffer was split),
* reduce-scatters emitted in reverse-topological order (last backward bucket
  first — its gradient is ready first) and all-gathers forward (first
  forward-pass bucket first). Channel ids are assigned in trace order by
  jax, so emission order IS channel-id order in the pre-opt dump.
"""
from __future__ import annotations

from collections import Counter
from typing import List, Optional, Sequence, Tuple

from repro.analysis.hlo_ir import HloInstruction, HloModule
from repro.analysis.rules.base import (Finding, LintContext, Rule,
                                       sized_collectives)


def _by_channel(ops: Sequence[Tuple[object, HloInstruction]]
                ) -> List[Tuple[object, HloInstruction]]:
    return sorted(ops, key=lambda ci: (ci[1].channel_id or 0,
                                       ci[1].line_no))


class OneRsOneAgRule(Rule):
    """Each FSDP (bucket x dtype) buffer crosses the wire exactly once per
    direction: one reduce-scatter for its gradient, one all-gather for its
    params. A duplicate means a retrace emitted the collective twice (2x
    wire traffic); a missing one means a bucket silently fell out of sync.
    Compared as multisets of flat-buffer element counts.
    """
    id = "ONE-RS-ONE-AG"
    fix_hint = ("one flat buffer per (bucket, dtype): check FsdpLayout "
                "grouping and that grad_sync_fsdp / fsdp_all_gather are "
                "called once per buffer per step")

    def _diff(self, module, ops, expected: Optional[List[int]],
              kind: str) -> List[Finding]:
        if expected is None:
            return []
        got = Counter(i.elements() for _, i in ops)
        want = Counter(expected)
        out: List[Finding] = []
        for size in sorted(got - want):
            comp, instr = next((c, i) for c, i in ops
                               if i.elements() == size)
            out.append(self.op_finding(
                f"surplus {kind} for a {size}-element buffer: "
                f"{got[size]} found, {want[size]} expected", comp, instr))
        for size in sorted(want - got):
            out.append(self.finding(
                f"missing {kind} for a {size}-element buffer "
                f"({want[size]} expected, {got[size]} found)"))
        return out

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        rs = sized_collectives(module, ["reduce-scatter"], ctx)
        ag = sized_collectives(module, ["all-gather"], ctx)
        return (self._diff(module, rs, ctx.expected_rs_elements,
                           "reduce-scatter")
                + self._diff(module, ag, ctx.expected_ag_elements,
                             "all-gather"))


class BucketOrderRule(Rule):
    """Bucket collectives must be emitted in schedule order: reduce-scatters
    (and plain-DP all-reduces) reverse-topological — the last backward
    bucket's gradient is complete first, so its collective must launch first
    to overlap with the rest of the backward pass — and all-gathers forward,
    matching forward-pass consumption order. Emission order is read off
    channel ids (jax assigns them in trace order).

    This is the rule a ``make_buckets(order='tree')`` regression trips.
    """
    id = "BUCKET-ORDER"
    fix_hint = ("emit grad collectives in reverse-topological bucket order "
                "(make_buckets(..., order='reverse_topo')); all-gathers in "
                "forward order")

    def _check_seq(self, ops, expected: Optional[List[int]],
                   kind: str) -> List[Finding]:
        if expected is None:
            return []
        ordered = _by_channel(ops)
        got = [i.elements() for _, i in ordered]
        if sorted(got) != sorted(expected):
            return []  # wrong population — ONE-RS-ONE-AG owns that report
        if got == expected:
            return []
        comp, instr = ordered[0]
        return [self.op_finding(
            f"{kind} emission order {got} does not match schedule order "
            f"{expected} (channel-id order = trace order)", comp, instr)]

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        rs = sized_collectives(module, ["reduce-scatter"], ctx)
        ag = sized_collectives(module, ["all-gather"], ctx)
        ar = sized_collectives(module, ["all-reduce"], ctx)
        out = self._check_seq(rs, ctx.expected_rs_elements, "reduce-scatter")
        out += self._check_seq(ag, ctx.expected_ag_elements, "all-gather")
        out += self._check_seq(ar, ctx.expected_ar_elements, "all-reduce")
        return out


class DonationLostRule(Rule):
    """The canonical train/solver steps donate their state buffers
    (``donate_argnums``); if the lowered module carries neither an
    ``input_output_alias`` nor a ``buffer_donor`` header entry, donation was
    silently dropped (a wrapper re-captured the arg, or a non-jit path) and
    peak memory doubles on the donated tree.
    """
    id = "DONATION-LOST"
    fix_hint = ("pass state positionally through jax.jit(donate_argnums=...) "
                "with no intervening closure capture; check the wrapper "
                "did not rebuild the pytree outside the jit boundary")

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        if not ctx.expect_donation:
            return []
        if module.n_aliased or module.n_donors:
            return []
        return [self.finding(
            "module expects donated state but header has no "
            "input_output_alias / buffer_donor entries — donation lost")]
