"""Grad-sync bucket rules: BUCKET-ORDER, ONE-RS-ONE-AG, DONATION-LOST.

Expectations come from the *same* code the runtime uses — ``make_buckets`` /
``fsdp_layout`` element counts, fed in through :class:`LintContext` — so the
lint can never drift from the implementation. The rules then check the
lowered module against them:

* exactly one reduce-scatter and one all-gather per (bucket x dtype) flat
  buffer (no retrace duplicated a collective, no buffer was split),
* reduce-scatters emitted in reverse-topological order (last backward bucket
  first — its gradient is ready first) and all-gathers forward (first
  forward-pass bucket first). Channel ids are assigned in trace order by
  jax, so emission order IS channel-id order in the pre-opt dump.
"""
from __future__ import annotations

from collections import Counter
from typing import List, Optional, Sequence, Tuple

from repro.analysis.hlo_ir import (HloComputation, HloInstruction, HloModule,
                                   is_compute)
from repro.analysis.rules.base import (Finding, LintContext, Rule,
                                       sized_collectives)


def _by_channel(ops: Sequence[Tuple[object, HloInstruction]]
                ) -> List[Tuple[object, HloInstruction]]:
    return sorted(ops, key=lambda ci: (ci[1].channel_id or 0,
                                       ci[1].line_no))


class OneRsOneAgRule(Rule):
    """Each FSDP (bucket x dtype) buffer crosses the wire exactly once per
    direction: one reduce-scatter for its gradient, one all-gather for its
    params. A duplicate means a retrace emitted the collective twice (2x
    wire traffic); a missing one means a bucket silently fell out of sync.
    Compared as multisets of flat-buffer element counts.
    """
    id = "ONE-RS-ONE-AG"
    fix_hint = ("one flat buffer per (bucket, dtype): check FsdpLayout "
                "grouping and that grad_sync_fsdp / fsdp_all_gather are "
                "called once per buffer per step")

    def _diff(self, module, ops, expected: Optional[List[int]],
              kind: str) -> List[Finding]:
        if expected is None:
            return []
        got = Counter(i.elements() for _, i in ops)
        want = Counter(expected)
        out: List[Finding] = []
        for size in sorted(got - want):
            comp, instr = next((c, i) for c, i in ops
                               if i.elements() == size)
            out.append(self.op_finding(
                f"surplus {kind} for a {size}-element buffer: "
                f"{got[size]} found, {want[size]} expected", comp, instr))
        for size in sorted(want - got):
            out.append(self.finding(
                f"missing {kind} for a {size}-element buffer "
                f"({want[size]} expected, {got[size]} found)"))
        return out

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        rs = sized_collectives(module, ["reduce-scatter"], ctx)
        ag = sized_collectives(module, ["all-gather"], ctx)
        return (self._diff(module, rs, ctx.expected_rs_elements,
                           "reduce-scatter")
                + self._diff(module, ag, ctx.expected_ag_elements,
                             "all-gather"))


class BucketOrderRule(Rule):
    """Bucket collectives must be emitted in schedule order: reduce-scatters
    (and plain-DP all-reduces) reverse-topological — the last backward
    bucket's gradient is complete first, so its collective must launch first
    to overlap with the rest of the backward pass — and all-gathers forward,
    matching forward-pass consumption order. Emission order is read off
    channel ids (jax assigns them in trace order).

    This is the rule a ``make_buckets(order='tree')`` regression trips.
    """
    id = "BUCKET-ORDER"
    fix_hint = ("emit grad collectives in reverse-topological bucket order "
                "(make_buckets(..., order='reverse_topo')); all-gathers in "
                "forward order")

    def _check_seq(self, ops, expected: Optional[List[int]],
                   kind: str) -> List[Finding]:
        if expected is None:
            return []
        ordered = _by_channel(ops)
        got = [i.elements() for _, i in ordered]
        if sorted(got) != sorted(expected):
            return []  # wrong population — ONE-RS-ONE-AG owns that report
        if got == expected:
            return []
        comp, instr = ordered[0]
        return [self.op_finding(
            f"{kind} emission order {got} does not match schedule order "
            f"{expected} (channel-id order = trace order)", comp, instr)]

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        rs = sized_collectives(module, ["reduce-scatter"], ctx)
        ag = sized_collectives(module, ["all-gather"], ctx)
        ar = sized_collectives(module, ["all-reduce"], ctx)
        out = self._check_seq(rs, ctx.expected_rs_elements, "reduce-scatter")
        out += self._check_seq(ag, ctx.expected_ag_elements, "all-gather")
        out += self._check_seq(ar, ctx.expected_ar_elements, "all-reduce")
        return out


def ag_live_spans(module: HloModule, ctx: LintContext
                  ) -> List[Tuple[object, HloInstruction, int, int]]:
    """Live span of every sized all-gather's result: ``(comp, ag, def_line,
    last_compute_line)``, the last consumer reached through non-compute data
    movement (unpack slices/reshapes, tuple plumbing, async -done halves).
    Shared by AG-ADJACENCY and the ``fsdp_mem`` benchmark probe — the lint
    bounds the COUNT of simultaneously live gathered buffers, the probe sums
    their BYTES."""
    by_comp: dict = {}
    for comp, instr in sized_collectives(module, ["all-gather"], ctx):
        by_comp.setdefault(comp.name, (comp, []))[1].append(instr)
    spans: List[Tuple[object, HloInstruction, int, int]] = []
    for comp, ags in by_comp.values():
        users = comp.users_map()
        for ag in ags:
            seen = {ag.name}
            frontier = [ag.name]
            last: Optional[int] = None
            while frontier:
                name = frontier.pop()
                for user in users.get(name, ()):
                    if user.name in seen:
                        continue
                    seen.add(user.name)
                    if is_compute(module, user):
                        if last is None or user.line_no > last:
                            last = user.line_no
                    else:
                        frontier.append(user.name)
            if last is not None and last > ag.line_no:
                spans.append((comp, ag, ag.line_no, last))
    return spans


class AgAdjacencyRule(Rule):
    """Streaming ZeRO-3 working-set bound: each FSDP all-gather must be
    *dataflow-adjacent* to the layer that consumes it — the gathered buffer
    is live from the gather until its LAST compute consumer (reached through
    the unpack slices/reshapes), and at most ``fsdp_working_set`` gathered
    flat buffers may be live at once. Streaming satisfies this because the
    backward REGATHERS each layer's bucket inside its remat region, so every
    forward gather dies within its own layer. A top-of-step gather-all
    schedule keeps every gathered buffer live into the backward (the weights
    are grad residuals), so all of them overlap and this rule trips — the
    invariant a first-consumer check cannot see, since the HLO printer sinks
    each instruction next to its first use.

    Active only when ``ctx.extra['fsdp_working_set']`` is set (the max
    number of simultaneously live gathered flat buffers).
    """
    id = "AG-ADJACENCY"
    fix_hint = ("gather each bucket at its consuming layer and regather in "
                "the backward (fsdp_streaming=True routes materialization "
                "through core.overlap.FsdpStream inside the layer's remat "
                "region) instead of fsdp_all_gather for the whole layout "
                "up front")

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        limit = ctx.extra.get("fsdp_working_set")
        if limit is None:
            return []
        by_comp: dict = {}
        for comp, ag, start, end in ag_live_spans(module, ctx):
            by_comp.setdefault(comp.name, (comp, []))[1].append(
                (ag, start, end))
        out: List[Finding] = []
        for comp, spans in by_comp.values():
            peak, peak_ag = 0, None
            for ag, start, _ in spans:   # live count only rises at a gather
                live = sum(1 for _, s, e in spans if s <= start < e)
                if live > peak:
                    peak, peak_ag = live, ag
            if peak > limit:
                out.append(self.op_finding(
                    f"{peak} gathered FSDP buffers live at once (working-set "
                    f"limit {limit}): gathered params survive to backward "
                    f"consumers instead of dying within their layer — a "
                    f"top-of-step gather-all schedule, not streaming",
                    comp, peak_ag))
        return out


class DonationLostRule(Rule):
    """The canonical train/solver steps donate their state buffers
    (``donate_argnums``); if the lowered module carries neither an
    ``input_output_alias`` nor a ``buffer_donor`` header entry, donation was
    silently dropped (a wrapper re-captured the arg, or a non-jit path) and
    peak memory doubles on the donated tree.
    """
    id = "DONATION-LOST"
    fix_hint = ("pass state positionally through jax.jit(donate_argnums=...) "
                "with no intervening closure capture; check the wrapper "
                "did not rebuild the pytree outside the jit boundary")

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        if not ctx.expect_donation:
            return []
        if module.n_aliased or module.n_donors:
            return []
        return [self.finding(
            "module expects donated state but header has no "
            "input_output_alias / buffer_donor entries — donation lost")]
