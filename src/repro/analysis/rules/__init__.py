"""Lint rule registry for the HLO schedule linter.

Each rule encodes one HDOT overlap invariant as a check over the parsed HLO
module (``analysis/hlo_ir.py``). Rules are pure: module + context in,
structured findings out. Register new rules by appending to ``ALL_RULES``.
"""
from repro.analysis.rules.base import (Finding, LintContext, Rule, Severity,
                                       annotate_wire_bytes)
from repro.analysis.rules.buckets import (AgAdjacencyRule, BucketOrderRule,
                                          DonationLostRule, OneRsOneAgRule)
from repro.analysis.rules.schedule import (DeadDrainRule, NoOverlapWindowRule,
                                           PairCountRule)
from repro.analysis.rules.wire import WireWidenRule

ALL_RULES = (
    DeadDrainRule(),
    PairCountRule(),
    BucketOrderRule(),
    OneRsOneAgRule(),
    WireWidenRule(),
    NoOverlapWindowRule(),
    AgAdjacencyRule(),
    DonationLostRule(),
)

RULES_BY_ID = {r.id: r for r in ALL_RULES}

__all__ = [
    "ALL_RULES", "RULES_BY_ID", "Finding", "LintContext", "Rule", "Severity",
    "annotate_wire_bytes", "DeadDrainRule", "PairCountRule", "BucketOrderRule",
    "OneRsOneAgRule", "WireWidenRule", "NoOverlapWindowRule",
    "AgAdjacencyRule", "DonationLostRule",
]
