"""Overlap-schedule rules: DEAD-DRAIN, PAIR-COUNT, NO-OVERLAP-WINDOW.

These three encode the core HDOT claims about the halo-exchange schedule:
no exchange is launched whose result nobody computes on (the PR-3 drain-step
bug), each mesh axis exchanges exactly one fwd+bwd ppermute pair per unrolled
step (over-decomposition did not duplicate traffic), and every non-trivial
collective has *some* computation it is dataflow-independent of (the static
precondition for the async scheduler to hide it).

All three lint the PRE-optimization HLO (``lowered.compiler_ir('hlo')``):
that dump preserves trace order and has not had dead code eliminated, so a
drain exchange the Python schedule emits pointlessly is still visible even
though XLA would DCE it — the lint catches the *schedule* bug, not whether
XLA happened to clean up after it.
"""
from __future__ import annotations

from collections import Counter
from typing import List

from repro.analysis.hlo_ir import (HloModule, computation_has_compute,
                                   independent_compute, reaches_live_compute)
from repro.analysis.rules.base import (Finding, LintContext, Rule,
                                       sized_collectives)


class DeadDrainRule(Rule):
    """A collective-permute whose result never reaches compute or the program
    output is a dead drain exchange: pure wire traffic with no consumer.

    This is exactly the PR-3 regression: an unpeeled halo_scan issues the
    step-N exchange whose halos no step ever reads. Detected by tuple-aware
    interprocedural taint from each ppermute result.
    """
    id = "DEAD-DRAIN"
    fix_hint = ("peel the final exchange out of the steady-state loop "
                "(halo_scan(..., peel=True)) so the drain step computes "
                "without communicating")

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        out = []
        for comp, instr in module.collectives(["collective-permute"]):
            if not reaches_live_compute(module, comp, instr):
                out.append(self.op_finding(
                    f"collective-permute result is dead: no compute or "
                    f"program output ever reads it "
                    f"(pairs={list(instr.source_target_pairs)})",
                    comp, instr))
        return out


class PairCountRule(Rule):
    """Collective-permute pairs per axis per unrolled step must match the
    schedule's arithmetic: 2 * axes * steps for a peeled halo scan (each axis
    sends one forward + one backward halo per step; the peeled drain step
    sends none). More permutes means duplicated halo traffic; fewer means a
    missing exchange. Also checks fwd/bwd balance: every source_target_pairs
    ring must appear exactly as often as its reverse.

    The same arithmetic covers the MoE EP all-to-alls when
    ``expected_a2a_total`` is set: the chunked schedule emits exactly 2Q
    (dispatch + combine over Q capacity slices) per traced MoE layer body,
    forward and backward alike — more means duplicated token traffic, fewer
    a silently-merged (monolithic) dispatch. a2a is its own transpose, so
    there is no fwd/bwd ring-balance counterpart.
    """
    id = "PAIR-COUNT"
    fix_hint = ("one ppermute pair per axis per step: check the unroll "
                "factor, drain peeling, and that over-decomposition shares "
                "one exchange across interior chunks")

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        permutes = module.collectives(["collective-permute"])
        out: List[Finding] = []
        if ctx.expected_permute_total is not None:
            got = len(permutes)
            if got != ctx.expected_permute_total:
                anchor = permutes[0] if permutes else None
                msg = (f"expected {ctx.expected_permute_total} "
                       f"collective-permutes for {ctx.target or 'schedule'}, "
                       f"found {got}")
                if anchor:
                    out.append(self.op_finding(msg, anchor[0], anchor[1]))
                else:
                    out.append(self.finding(msg))
        if ctx.expected_a2a_total is not None:
            a2as = module.collectives(["all-to-all"])
            got = len(a2as)
            if got != ctx.expected_a2a_total:
                msg = (f"expected {ctx.expected_a2a_total} all-to-alls for "
                       f"{ctx.target or 'schedule'} (2 x a2a_chunks per MoE "
                       f"layer body, dispatch + combine), found {got}")
                hint = ("the a2a_scan capacity chunking emits exactly "
                        "dispatch+combine per slice: check moe_a2a_chunks, "
                        "scan_layers (one textual body per direction) and "
                        "that remat is not re-tracing the MoE block")
                if a2as:
                    out.append(self.op_finding(msg, a2as[0][0], a2as[0][1],
                                               fix_hint=hint))
                else:
                    out.append(self.finding(msg, fix_hint=hint))
        # fwd/bwd balance: reverse of each ring pattern appears equally often
        pattern_counts = Counter(i.source_target_pairs for _, i in permutes)
        for pattern, n in sorted(pattern_counts.items()):
            rev = tuple(sorted((b, a) for a, b in pattern))
            canon = tuple(sorted(pattern))
            if canon == rev:
                continue  # self-inverse ring (2 devices)
            n_rev = sum(c for p, c in pattern_counts.items()
                        if tuple(sorted(p)) == rev)
            if n != n_rev:
                comp, instr = next((c, i) for c, i in permutes
                                   if i.source_target_pairs == pattern)
                out.append(self.op_finding(
                    f"unbalanced halo exchange: pattern {list(pattern)} "
                    f"appears {n}x but its reverse {n_rev}x — a shift "
                    f"without its counterpart is a lost halo",
                    comp, instr))
        return out


class NoOverlapWindowRule(Rule):
    """A collective with zero dataflow-independent compute in its computation
    cannot be overlapped no matter what the async scheduler does: every op
    either produces its operand or consumes its result. That is the
    two_phase shape (exchange -> barrier -> compute). HDOT lowerings must
    keep at least the interior chunks independent of every exchange.

    ``max_exposed_collectives`` allows the legitimate pipeline-fill ops
    (e.g. a scan's first exchange when steps stay in a while loop).
    """
    id = "NO-OVERLAP-WINDOW"
    fix_hint = ("restructure so interior compute does not consume the "
                "collective's result (over-decompose: boundary strips are "
                "the sole consumers, interior chunks run independently)")

    def check(self, module: HloModule, ctx: LintContext) -> List[Finding]:
        # a module with no compute anywhere (pure-communication microbench,
        # e.g. a standalone grad_sync jit) has nothing to hide latency
        # behind — the rule is about schedule shape, not about benchmarks
        if module.entry is None or not computation_has_compute(
                module, module.entry.name):
            return []
        exposed = []
        for comp, instr in sized_collectives(
                module, ["collective-permute", "all-reduce", "all-gather",
                         "reduce-scatter", "all-to-all"], ctx):
            if not independent_compute(module, comp, instr,
                                       min_elements=ctx.scalar_elements + 1):
                exposed.append((comp, instr))
        if len(exposed) <= ctx.max_exposed_collectives:
            return []
        return [self.op_finding(
            f"{instr.opcode} has zero dataflow-independent compute in "
            f"{comp.name}: nothing can hide its latency "
            f"({len(exposed)} exposed, "
            f"{ctx.max_exposed_collectives} allowed)",
            comp, instr) for comp, instr in exposed]
