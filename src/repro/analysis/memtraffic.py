"""Analytic per-chip HBM traffic model (the roofline memory term).

XLA-CPU's ``cost_analysis()['bytes accessed']`` counts per-instruction operand
bytes on the *CPU*-optimized module, which barely fuses — measured 5-6x above
theory for a plain matmul (EXPERIMENTS.md §Roofline methodology). It is kept
in the dry-run JSON as an upper bound, but the roofline t_mem uses this
analytic model of what a TPU actually moves through HBM:

train (per step, per chip):
    weights   : read fwd + read remat + read bwd             3 x P
    grads     : write + read (optimizer)                     2 x P
    optimizer : m,v read+write, p read+write                 4 x M + 2 x P
    activs    : residual-granularity saves r/w (remat=full saves layer inputs
                only; intermediates are recomputed, traffic ~ VMEM-resident)
    attention : flash kernel re-reads KV once per q-block
decode (per token, per chip):
    weights read once + KV cache read + one-slot write
prefill:
    weights read + fwd activations + cache write + flash KV re-reads

Every coefficient is spelled out below; the model intentionally errs on the
optimistic (fused-TPU) side, making t_mem a *lower* bound — i.e. a cell
reported memory-bound truly is.
"""
from __future__ import annotations

from typing import Any

import numpy as np

from repro.config.base import ModelConfig
from repro.config.shapes import ShapeConfig

PyTree = Any


def _dtype_bytes(dt) -> float:
    return np.dtype(dt).itemsize


def collective_wire_bytes(kind: str, result_bytes: float,
                          group_size: int) -> float:
    """Ring-model per-chip wire bytes for one collective, from its *result*
    buffer size. Single source of truth for the dry-run HLO parser
    (``analysis/hlo.py``) and the per-finding traffic annotation in
    ``analysis/hlo_lint.py``:

      all-gather         operand * (g-1) = result/g * (g-1)
      reduce-scatter     result * (g-1)
      all-reduce         2 * result * (g-1) / g
      all-to-all         result * (g-1) / g
      collective-permute result                       (point-to-point)
    """
    g = max(int(group_size), 1)
    if kind == "all-gather":
        return result_bytes / g * (g - 1)
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)  # collective-permute / unknown


def sharded_bytes(specs: PyTree, axes: PyTree, ctx) -> float:
    """Per-chip bytes of a spec tree under the resolver's placements."""
    import jax

    from repro.sharding.rules import resolve_pspec

    total = 0.0

    def one(leaf, ax):
        nonlocal total
        spec = resolve_pspec(leaf.shape, ax, ctx)
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for n in names:
                denom *= ctx.axis_size(n)
        total += int(np.prod(leaf.shape)) * _dtype_bytes(leaf.dtype) / denom

    jax.tree.map(one, specs, axes, is_leaf=lambda x: hasattr(x, "shape"))
    return total


def _ff_active(cfg: ModelConfig) -> float:
    if cfg.family == "moe":
        return cfg.moe.top_k * cfg.moe.d_ff_expert * cfg.moe.capacity_factor
    if cfg.family == "ssm":
        return 2.0 * cfg.ssm.d_inner(cfg.d_model)
    return float(cfg.d_ff)


def activation_traffic_per_layer(cfg: ModelConfig, tokens_global: int,
                                 chips: int, passes: float) -> float:
    """Per-chip bytes for one layer's activation stream.

    Residual-granularity tensors (written fwd, read bwd): the block input,
    attention output, MLP input, MLP output (4 x d); the MLP hidden and
    attention q/k/v stay VMEM-resident in the fused TPU kernels (their HBM
    traffic is the remat *recompute*, already counted as weight re-reads).
    """
    t_chip = tokens_global / chips
    d = cfg.d_model
    bytes_bf16 = 2.0
    resident = 4.0 * d + 0.5 * _ff_active(cfg)   # spilled fraction of hidden
    return t_chip * resident * bytes_bf16 * passes


def flash_kv_traffic(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                     chunk: int = 1024) -> float:
    """Flash attention re-reads K,V once per query block (causal ~ 1/2)."""
    if cfg.family == "ssm":
        return 0.0
    s = shape.seq_len
    window = cfg.sliding_window or s
    kv_len = min(s, window)
    n_q_blocks = max(1, s // chunk)
    kv_bytes = (shape.global_batch * kv_len * cfg.num_kv_heads
                * cfg.resolved_head_dim * 2 * 2.0)
    return 0.5 * n_q_blocks * kv_bytes / chips


def hbm_traffic(cfg: ModelConfig, shape: ShapeConfig, chips: int,
                param_bytes_chip: float, moment_bytes_chip: float = 0.0,
                cache_bytes_chip: float = 0.0, remat: bool = True) -> float:
    """Per-chip HBM bytes for one step of this cell."""
    L = cfg.num_layers
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        weight_reads = (3.0 if remat else 2.0) * param_bytes_chip
        grad_traffic = 2.0 * param_bytes_chip
        opt_traffic = 4.0 * moment_bytes_chip + 2.0 * param_bytes_chip
        act = L * activation_traffic_per_layer(cfg, tokens, chips, passes=2.0)
        kv = L * flash_kv_traffic(cfg, shape, chips) * 3.0  # fwd+remat+bwd
        return weight_reads + grad_traffic + opt_traffic + act + kv
    if shape.kind == "prefill":
        act = L * activation_traffic_per_layer(cfg, tokens, chips, passes=1.0)
        kv = L * flash_kv_traffic(cfg, shape, chips)
        return param_bytes_chip + act + kv + cache_bytes_chip  # cache write
    # decode: params + full cache read + one-slot write (~0)
    return param_bytes_chip + cache_bytes_chip
