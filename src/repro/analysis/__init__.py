"""Roofline analysis: HLO collective parsing + three-term roofline model."""
from repro.analysis.hlo import collective_bytes, parse_collectives
from repro.analysis.roofline import HW, RooflineReport, roofline

__all__ = ["collective_bytes", "parse_collectives", "HW", "RooflineReport",
           "roofline"]
