"""HLO schedule linter: static analysis that proves the HDOT overlap shape.

The repo's performance story rests on *structural* properties of the lowered
program — peeled drains, one exchange pair per axis per step, reverse-topo
bucket emission, one RS/AG per FSDP buffer, grads crossing the wire at param
width, donated state actually aliased. Benchmarks only notice when these
break by a lot; this linter notices when they break at all, by parsing the
PRE-optimization HLO (trace order, no DCE — the schedule as Python emitted
it, not as XLA cleaned it up) and checking every invariant as a lint rule.

Usage:
    python -m repro.analysis.hlo_lint                 # lint all canonical targets
    python -m repro.analysis.hlo_lint -t halo1d,rk3_2d --json findings.json
    python -m repro.analysis.hlo_lint --list

Library use (tests, CI):
    from repro.analysis.hlo_lint import lint_text
    report = lint_text(hlo_text, ctx)
    assert report.ok, report.render()

Rule catalog and fix hints: docs/analysis.md.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.analysis.hlo_ir import parse_hlo_module
from repro.analysis.rules import ALL_RULES, RULES_BY_ID, LintContext, Severity
from repro.analysis.rules.base import Finding, Rule, annotate_wire_bytes


@dataclass
class LintReport:
    target: str
    module_name: str
    findings: List[Finding] = field(default_factory=list)
    n_collectives: int = 0
    wire_bytes: float = 0.0          # memtraffic ring-model module total

    @property
    def ok(self) -> bool:
        return not any(f.severity == Severity.ERROR for f in self.findings)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == Severity.ERROR]

    def to_dict(self) -> dict:
        return {
            "target": self.target, "module": self.module_name,
            "ok": self.ok, "n_collectives": self.n_collectives,
            "wire_bytes": round(self.wire_bytes, 1),
            "findings": [f.to_dict() for f in self.findings],
        }

    def render(self) -> str:
        head = (f"{'PASS' if self.ok else 'FAIL'} {self.target:16s} "
                f"({self.n_collectives} collectives, "
                f"{self.wire_bytes / 1e3:.1f} kB wire)")
        if not self.findings:
            return head
        return head + "\n" + "\n".join(str(f) for f in self.findings)


def lint_text(hlo_text: str, ctx: Optional[LintContext] = None,
              rules: Optional[Sequence[Rule]] = None,
              target: str = "") -> LintReport:
    """Parse `hlo_text` and run the rule set against it."""
    ctx = ctx or LintContext()
    module = parse_hlo_module(hlo_text)
    report = LintReport(target=target or ctx.target or module.name,
                        module_name=module.name)
    collectives = module.collectives()
    report.n_collectives = len(collectives)
    report.wire_bytes = sum(annotate_wire_bytes(i) or 0.0
                            for _, i in collectives)
    for rule in (rules if rules is not None else ALL_RULES):
        report.findings.extend(rule.check(module, ctx))
    report.findings.sort(key=lambda f: (Severity.ORDER.get(f.severity, 9),
                                        f.rule, f.line))
    return report


def lint_target(name: str, rules: Optional[Sequence[Rule]] = None
                ) -> LintReport:
    """Lower one canonical program (see ``lint_targets``) and lint it."""
    from repro.analysis import lint_targets

    tgt = lint_targets.build(name)
    return lint_text(tgt.hlo_text, tgt.ctx, rules=rules, target=name)


# ------------------------------------------------------------------- CLI
def _select_rules(only: Optional[str]) -> Optional[List[Rule]]:
    if not only:
        return None
    out = []
    for rid in only.split(","):
        rid = rid.strip()
        if rid not in RULES_BY_ID:
            raise SystemExit(f"unknown rule {rid!r}; known: "
                             f"{', '.join(sorted(RULES_BY_ID))}")
        out.append(RULES_BY_ID[rid])
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.hlo_lint",
        description="Lint canonical HDOT lowerings for schedule regressions.")
    ap.add_argument("-t", "--targets", default="",
                    help="comma-separated target names (default: all)")
    ap.add_argument("-r", "--rules", default="",
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write the findings report as JSON")
    ap.add_argument("--devices", type=int, default=8,
                    help="host-platform device count for lowering (default 8)")
    ap.add_argument("--list", action="store_true",
                    help="list targets and rules, then exit")
    args = ap.parse_args(argv)

    # must precede the first jax import anywhere in the process
    if "jax" not in sys.modules:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}").strip()

    from repro.analysis import lint_targets

    if args.list:
        print("targets:")
        for name, doc in lint_targets.describe():
            print(f"  {name:16s} {doc}")
        print("rules:")
        for rule in ALL_RULES:
            print(f"  {rule.id:18s} [{rule.severity}] "
                  f"{(rule.__doc__ or '').strip().splitlines()[0]}")
        return 0

    names = ([n.strip() for n in args.targets.split(",") if n.strip()]
             or lint_targets.all_targets())
    rules = _select_rules(args.rules)
    reports = []
    for name in names:
        report = lint_target(name, rules=rules)
        reports.append(report)
        print(report.render())
    n_err = sum(len(r.errors) for r in reports)
    print(f"linted {len(reports)} targets: "
          f"{sum(r.ok for r in reports)} pass, "
          f"{sum(not r.ok for r in reports)} fail ({n_err} errors)")
    if args.json:
        payload = {
            "targets": [r.to_dict() for r in reports],
            "ok": all(r.ok for r in reports),
            "rules": sorted(RULES_BY_ID),
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}")
    return 0 if all(r.ok for r in reports) else 1


if __name__ == "__main__":
    raise SystemExit(main())
