"""Parse collective traffic out of optimized HLO text.

``cost_analysis()`` has no collective term, so the dry-run derives it from the
compiled module: every ``all-gather`` / ``all-reduce`` / ``reduce-scatter`` /
``all-to-all`` / ``collective-permute`` op definition is located, its result
shape(s) and replica-group size parsed, and converted to per-chip wire bytes
under the standard ring model:

  op                 result vs operand     wire bytes per chip (ring)
  all-gather         R = g * O             O * (g-1)            ~= R
  reduce-scatter     R = O / g             R * (g-1)            ~= O
  all-reduce         R = O                 2 * O * (g-1) / g    ~= 2 O
  all-to-all         R = O                 O * (g-1) / g        ~= O
  collective-permute R = O                 O

SPMD modules are per-device, so parsed sizes are already per-chip. Both the
raw operand-sum (the brief's metric) and the ring-model wire bytes are
reported; the roofline collective term uses the ring model (documented in
EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.analysis.memtraffic import collective_wire_bytes

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one typed shape, e.g. bf16[4096,14336] (layout braces optional)
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*[a-z0-9]*)\[([0-9,]*)\]")
# op definition: "%name = <result> <op>(" where <op> is a collective
_OP_RE = re.compile(
    r"=\s+(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?P<suffix>-start|-done)?\s*\(")
# replica_groups=[4,2]<=[8]  (4 groups of 2)  |  replica_groups={{0,1},{2,3}}
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
# collective-permute has source_target_pairs instead of replica_groups
_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(text: str) -> float:
    """Sum byte sizes of all typed shapes appearing in `text`."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue  # token[] etc.
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 2  # collective-permute / unknown: treat as point-to-point


@dataclass
class CollectiveOp:
    kind: str                  # base op name (suffix stripped)
    result_bytes: float        # per-chip result buffer size
    operand_bytes: float       # per-chip operand size (derived)
    wire_bytes: float          # ring-model per-chip wire traffic
    group_size: int
    dtype: str = ""
    line: str = ""

    @property
    def wire_bytes_bf16eq(self) -> float:
        """XLA-CPU upcasts every bf16 dot to f32 BEFORE SPMD partitioning
        (measured in the pre-build probe: the partial-sum all-reduce is
        f32 even with preferred_element_type=bf16), so large f32 collectives
        in a bf16 model carry 2x the bytes a TPU lowering would move. This
        column halves f32 ops >= 1 MiB — the TPU-equivalent wire traffic."""
        if self.dtype == "f32" and self.wire_bytes >= 2**20:
            return self.wire_bytes / 2
        return self.wire_bytes


@dataclass
class CollectiveSummary:
    ops: List[CollectiveOp] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    @property
    def total_wire_bytes_bf16eq(self) -> float:
        return sum(o.wire_bytes_bf16eq for o in self.ops)

    @property
    def total_operand_bytes(self) -> float:
        return sum(o.operand_bytes for o in self.ops)

    def by_kind(self) -> Dict[str, Tuple[int, float]]:
        out: Dict[str, Tuple[int, float]] = {}
        for o in self.ops:
            n, b = out.get(o.kind, (0, 0.0))
            out[o.kind] = (n + 1, b + o.wire_bytes)
        return out

    def __str__(self) -> str:
        rows = [f"  {k:20s} n={n:4d}  wire={b/1e9:10.3f} GB"
                for k, (n, b) in sorted(self.by_kind().items())]
        rows.append(f"  {'TOTAL':20s} n={len(self.ops):4d}  "
                    f"wire={self.total_wire_bytes/1e9:10.3f} GB")
        return "\n".join(rows)


def parse_collectives(hlo_text: str) -> CollectiveSummary:
    summary = CollectiveSummary()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("suffix") == "-done":
            continue  # -start already counted
        kind = m.group("op")
        result = m.group("result")
        rb = _shape_bytes(result)
        if kind == "all-gather" and m.group("suffix") == "-start":
            # start result tuple carries (operand, result); result is larger
            rb = rb / 2 if rb else rb
        g = _group_size(line)
        wire = collective_wire_bytes(kind, rb, g)
        if kind == "all-gather":
            operand = rb / max(g, 1)
        elif kind == "reduce-scatter":
            operand = rb * g
        else:  # all-reduce / all-to-all / collective-permute
            operand = rb
        dts = {dt for dt, _ in _SHAPE_RE.findall(m.group("result"))
               if dt in _DTYPE_BYTES}
        dtype = dts.pop() if len(dts) == 1 else ",".join(sorted(dts))
        summary.ops.append(CollectiveOp(kind, rb, operand, wire, g, dtype,
                                        line.strip()[:160]))
    return summary


def collective_bytes(hlo_text: str) -> float:
    """Per-chip ring-model wire bytes for the whole module."""
    return parse_collectives(hlo_text).total_wire_bytes


def count_ops(hlo_text: str, name: str) -> int:
    """Count op definitions of a given HLO opcode (e.g. 'fusion', 'dot',
    'while') — used by perf iterations to spot remat recompute and layout
    churn."""
    pat = re.compile(rf"=\s+(?:\([^)]*\)|\S+)\s+{re.escape(name)}[.\s(]")
    return sum(1 for line in hlo_text.splitlines() if pat.search(line))
