"""A real HLO-text parser: modules, computations, instructions, dataflow.

`analysis/hlo.py` answers "how many bytes cross the wire" with line-local
regexes; the schedule *linter* (`analysis/hlo_lint.py`) needs actual program
structure — which op consumes which, across `call` boundaries, with tuple
elements tracked — so this module parses HLO text (both the pre-optimization
trace-order dump from ``lowered.compiler_ir('hlo').as_hlo_text()`` and the
post-compile ``compiled.as_text()``) into a small IR:

  HloModule ── computations{name: HloComputation} ── instructions[HloInstruction]

plus the graph queries the lint rules are built on:

  * ``users``/``operands`` maps per computation,
  * interprocedural *taint reachability* (`reaches_live_compute`): does a
    value ever feed arithmetic, tracking tuple-element indices through
    ``tuple``/``get-tuple-element``/``call`` so a dead drain exchange that
    rides a scan carry to an unused output is still recognized as dead,
  * intra-computation ancestor/descendant sets (`independent_compute`): the
    static form of "is there compute the scheduler could overlap this
    collective with".

Parsing is line-oriented and intentionally forgiving: unknown attributes ride
along as raw text, unknown opcodes parse fine. The linter must never crash on
an HLO dialect wobble — worst case a rule sees fewer ops and reports that.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

# dtype -> bytes per element (mirrors analysis/hlo.py, shared via memtraffic)
DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# Opcodes that DO math. Anything here (or a call/fusion/while that contains
# one) keeps a value "alive" for DEAD-DRAIN and counts as overlappable work
# for NO-OVERLAP-WINDOW. Data movement (slice/concat/reshape/...) is
# deliberately excluded: assembling a padded block is not compute.
COMPUTE_OPS = frozenset({
    "add", "subtract", "multiply", "divide", "dot", "convolution",
    "reduce", "reduce-window", "map", "sort", "scatter", "select-and-scatter",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "rsqrt", "sqrt", "cbrt", "power", "negate", "abs", "sign",
    "maximum", "minimum", "clamp", "select", "compare", "atan2", "remainder",
    "sine", "cosine", "tan", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "and", "or", "xor", "not", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "fusion", "cholesky",
    "triangular-solve", "fft", "erf", "expm1", "log1p",
})

# Container/control opcodes whose compute-ness is decided by their callee(s).
_CALLING_OPS = frozenset({"call", "while", "conditional", "fusion",
                          "custom-call", "async-start"})

# one typed shape: bf16[4096,64] (layout braces optional, handled outside)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?P<root>ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<type>\([^=]*?\)|\S+)\s+(?P<opcode>[\w\-]+)\(")
_COMP_RE = re.compile(
    r"^(?P<entry>ENTRY\s+)?%?(?P<name>[\w.\-]+)"
    r"(?:\s+\(.*\)\s*->\s*.+?)?\s*\{\s*$")
_NAME_TOKEN_RE = re.compile(r"%([\w.\-]+)")
# HLO interleaves position comments into long tuples/operand lists
# ("/*index=5*/"); strip before matching
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_BARE_NAME_RE = re.compile(r"^[A-Za-z_][\w.\-]*$")
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")
_INDEX_RE = re.compile(r"\bindex=(\d+)")
_CALLEE_RE = re.compile(r"(?:to_apply|body|condition|called_computations=\{|"
                        r"branch_computations=\{)[=]?%?([\w.\-]+)")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")


@dataclass
class HloInstruction:
    name: str
    opcode: str
    shapes: Tuple[Tuple[str, Tuple[int, ...]], ...]  # (dtype, dims) per part
    is_tuple: bool
    operands: Tuple[str, ...]
    attr_text: str                      # raw text after the operand list
    is_root: bool
    line_no: int                        # 1-based into the linted text
    raw: str

    # ------------------------------------------------------------- accessors
    @property
    def channel_id(self) -> Optional[int]:
        m = _CHANNEL_RE.search(self.attr_text)
        return int(m.group(1)) if m else None

    @property
    def tuple_index(self) -> Optional[int]:
        m = _INDEX_RE.search(self.attr_text)
        return int(m.group(1)) if m else None

    @property
    def called_computations(self) -> Tuple[str, ...]:
        names = _CALLEE_RE.findall(self.attr_text)
        # branch/called lists: "a, b, c}" — pull every name in the braces
        m = re.search(r"(?:called_computations|branch_computations)="
                      r"\{([^}]*)\}", self.attr_text)
        if m:
            names = [n for n in names if n not in m.group(1)]
            names += [t.strip().lstrip("%")
                      for t in m.group(1).split(",") if t.strip()]
        return tuple(dict.fromkeys(names))

    @property
    def source_target_pairs(self) -> Tuple[Tuple[int, int], ...]:
        m = _PAIRS_RE.search(self.attr_text)
        if not m:
            return ()
        return tuple(tuple(int(v) for v in p.split(","))
                     for p in re.findall(r"\{(\d+,\d+)\}", m.group(0)))

    @property
    def replica_group_size(self) -> int:
        m = _GROUPS_IOTA_RE.search(self.attr_text)
        if m:
            return int(m.group(2))
        m = _GROUPS_LIST_RE.search(self.attr_text)
        if m:
            return max(1, m.group(1).count(",") + 1)
        return 2  # collective-permute / unknown: point-to-point

    @property
    def collective_kind(self) -> Optional[str]:
        for k in COLLECTIVE_OPS:
            if self.opcode == k or self.opcode in (f"{k}-start", f"{k}-done"):
                return k
        return None

    def elements(self, part: Optional[int] = None) -> int:
        parts = self.shapes if part is None else (self.shapes[part],)
        total = 0
        for _, dims in parts:
            n = 1
            for d in dims:
                n *= d
            total += n
        return total

    def result_bytes(self) -> float:
        return sum(self.elements(i) * DTYPE_BYTES.get(dt, 0)
                   for i, (dt, _) in enumerate(self.shapes))

    @property
    def dtypes(self) -> Tuple[str, ...]:
        return tuple(dt for dt, _ in self.shapes)


@dataclass
class HloComputation:
    name: str
    is_entry: bool
    instructions: List[HloInstruction] = field(default_factory=list)
    by_name: Dict[str, HloInstruction] = field(default_factory=dict)

    @property
    def root(self) -> Optional[HloInstruction]:
        for i in self.instructions:
            if i.is_root:
                return i
        return self.instructions[-1] if self.instructions else None

    def users_map(self) -> Dict[str, List[HloInstruction]]:
        users: Dict[str, List[HloInstruction]] = {}
        for instr in self.instructions:
            for op in instr.operands:
                users.setdefault(op, []).append(instr)
        return users


@dataclass
class HloModule:
    name: str
    header: str
    computations: Dict[str, HloComputation]
    entry: Optional[HloComputation]
    n_aliased: int                      # input_output_alias entries
    n_donors: int                       # buffer_donor entries (pre-opt)

    def all_instructions(self) -> Iterator[Tuple[HloComputation, HloInstruction]]:
        for comp in self.computations.values():
            for instr in comp.instructions:
                yield comp, instr

    def collectives(self, kinds: Optional[Sequence[str]] = None
                    ) -> List[Tuple[HloComputation, HloInstruction]]:
        """Collective op *definitions* ('-done' halves skipped so async pairs
        count once), in text order."""
        out = []
        for comp, instr in self.all_instructions():
            k = instr.collective_kind
            if k is None or instr.opcode.endswith("-done"):
                continue
            if kinds is None or k in kinds:
                out.append((comp, instr))
        return out

    def call_sites(self, callee: str) -> List[Tuple[HloComputation, HloInstruction]]:
        return [(c, i) for c, i in self.all_instructions()
                if callee in i.called_computations]


# ------------------------------------------------------------------ parsing
def _parse_shapes(type_text: str) -> Tuple[Tuple[Tuple[str, Tuple[int, ...]], ...], bool]:
    is_tuple = type_text.startswith("(")
    shapes = []
    for dt, dims in _SHAPE_RE.findall(type_text):
        if dt not in DTYPE_BYTES and dt != "token":
            continue
        dims_t = tuple(int(d) for d in dims.split(",")) if dims else ()
        shapes.append((dt, dims_t))
    return tuple(shapes), is_tuple


def _split_operands(text: str, opcode: str) -> Tuple[str, ...]:
    """Operand names from the region inside the op's parens. Handles both the
    bare pre-opt form `add(add.14, slice.15)` and the typed post-opt form
    `add(f32[1,4]{1,0} %add.55, ...)`."""
    if opcode in ("parameter", "constant", "iota"):
        return ()
    if "%" in text:
        return tuple(_NAME_TOKEN_RE.findall(text))
    out = []
    for tok in text.split(","):
        tok = tok.strip()
        # strip a leading type annotation if present without %
        if "[" in tok and "]" in tok and " " in tok:
            tok = tok.rsplit(" ", 1)[-1]
        if _BARE_NAME_RE.match(tok):
            out.append(tok)
    return tuple(out)


def _operand_region(line: str, start: int) -> Tuple[str, int]:
    """Text inside the balanced parens opening at `start`; returns (region,
    index one past the closing paren). Unterminated lines (truncated dumps)
    return the remainder."""
    depth, i = 0, start
    while i < len(line):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:i], i + 1
        i += 1
    return line[start + 1:], len(line)


def _count_header_entries(header: str, key: str, sep: str) -> int:
    m = re.search(re.escape(key) + r"=\{", header)
    if not m:
        return 0
    depth, out = 1, []
    for c in header[m.end():]:
        if c == "{":
            depth += 1
        elif c == "}":
            depth -= 1
            if depth == 0:
                break
        out.append(c)
    return "".join(out).count(sep)


def parse_hlo_module(text: str) -> HloModule:
    lines = text.splitlines()
    header = ""
    name = ""
    comps: Dict[str, HloComputation] = {}
    entry: Optional[HloComputation] = None
    current: Optional[HloComputation] = None
    for ln_no, line in enumerate(lines, start=1):
        stripped = line.strip()
        if stripped.startswith("HloModule"):
            header = stripped
            m = re.match(r"HloModule\s+([\w.\-]+)", stripped)
            name = m.group(1) if m else ""
            continue
        if current is None:
            m = _COMP_RE.match(stripped)
            if m and not stripped.startswith(("//", "#")):
                current = HloComputation(name=m.group("name"),
                                         is_entry=bool(m.group("entry")))
            continue
        if stripped == "}":
            comps[current.name] = current
            if current.is_entry:
                entry = current
            current = None
            continue
        line = _COMMENT_RE.sub("", line)
        m = _INSTR_RE.match(line)
        if not m:
            continue
        region, end = _operand_region(line, m.end() - 1)
        shapes, is_tuple = _parse_shapes(m.group("type"))
        instr = HloInstruction(
            name=m.group("name"), opcode=m.group("opcode"), shapes=shapes,
            is_tuple=is_tuple,
            operands=_split_operands(region, m.group("opcode")),
            attr_text=line[end:], is_root=bool(m.group("root")),
            line_no=ln_no, raw=stripped)
        current.instructions.append(instr)
        current.by_name[instr.name] = instr
    if current is not None:  # unterminated dump: keep what we have
        comps[current.name] = current
        if current.is_entry and entry is None:
            entry = current
    n_alias = _count_header_entries(header, "input_output_alias", ":")
    n_donor = _count_header_entries(header, "buffer_donor", "(")
    return HloModule(name=name, header=header, computations=comps,
                     entry=entry, n_aliased=n_alias, n_donors=n_donor)


# ------------------------------------------------------------ graph queries
def computation_has_compute(module: HloModule, comp_name: str,
                            _seen: Optional[Set[str]] = None) -> bool:
    """Does this computation (or anything it calls) contain arithmetic?"""
    seen = _seen if _seen is not None else set()
    if comp_name in seen:
        return False
    seen.add(comp_name)
    comp = module.computations.get(comp_name)
    if comp is None:
        return True  # unknown callee (e.g. custom-call target): conservative
    for instr in comp.instructions:
        if instr.opcode in COMPUTE_OPS:
            return True
        if instr.collective_kind is not None:
            continue  # a collective's to_apply reducer is not program compute
        for callee in instr.called_computations:
            if computation_has_compute(module, callee, seen):
                return True
    return False


def is_compute(module: HloModule, instr: HloInstruction) -> bool:
    if instr.opcode in COMPUTE_OPS:
        return True
    if instr.opcode in _CALLING_OPS:
        callees = instr.called_computations
        if not callees:
            # opaque target (Sharding custom-calls are pure data movement)
            return instr.opcode not in ("custom-call",)
        return any(computation_has_compute(module, c) for c in callees)
    return False


_WHOLE = -1  # taint marker: the whole value (vs a single tuple element)


def reaches_live_compute(module: HloModule, comp: HloComputation,
                         instr: HloInstruction) -> bool:
    """True if `instr`'s value can ever feed a compute op (or escape through
    the entry root / an opaque boundary). Tracks tuple-element indices through
    ``tuple`` / ``get-tuple-element`` and across ``call`` sites in both
    directions (operand -> callee parameter, callee root -> call result), so
    a drain exchange whose result only rides the scan carry to an unused
    output is correctly found dead. Conservative everywhere else: while /
    conditional / unknown consumers count as live."""
    # worklist of (computation, instruction, element) taints
    seen: Set[Tuple[str, str, int]] = set()
    work: List[Tuple[HloComputation, HloInstruction, int]] = [
        (comp, instr, _WHOLE)]
    users_maps: Dict[str, Dict[str, List[HloInstruction]]] = {}

    def users_of(c: HloComputation) -> Dict[str, List[HloInstruction]]:
        if c.name not in users_maps:
            users_maps[c.name] = c.users_map()
        return users_maps[c.name]

    while work:
        c, v, elem = work.pop()
        key = (c.name, v.name, elem)
        if key in seen:
            continue
        seen.add(key)
        tainted_users = users_of(c).get(v.name, [])
        if v.is_root:
            if c.is_entry:
                return True  # program output: live by definition
            for site_comp, site in module.call_sites(c.name):
                if site.opcode == "call":
                    work.append((site_comp, site, elem))
                else:
                    return True  # root of a while body / cond branch: live
        for u in tainted_users:
            if u.opcode == "tuple":
                positions = [k for k, op in enumerate(u.operands)
                             if op == v.name]
                if elem != _WHOLE:
                    # value is already an element of a tuple being re-tupled:
                    # nested tuple — give up precision, treat as live
                    return True
                for k in positions:
                    work.append((c, u, k))
                continue
            if u.opcode == "get-tuple-element":
                idx = u.tuple_index
                if elem == _WHOLE or idx is None or idx == elem:
                    work.append((c, u, _WHOLE))
                continue
            if u.opcode == "call" and u.called_computations:
                callee = module.computations.get(u.called_computations[0])
                if callee is None:
                    return True
                positions = [k for k, op in enumerate(u.operands)
                             if op == v.name]
                for p in callee.instructions:
                    if p.opcode != "parameter":
                        continue
                    m = re.match(r".*\((\d+)\)", p.raw)
                    pidx = int(m.group(1)) if m else None
                    if pidx in positions:
                        work.append((callee, p, _WHOLE))
                continue
            if is_compute(module, u):
                return True
            if u.opcode in ("while", "conditional", "custom-call",
                            "optimization-barrier", "all-reduce", "all-gather",
                            "reduce-scatter", "all-to-all", "send", "outfeed",
                            "dynamic-update-slice", "scatter"):
                return True  # consumed by control flow / comm / IO: live
            # pure data movement: keep chasing
            work.append((c, u, _WHOLE))
    return False


def _closure(comp: HloComputation, start: HloInstruction,
             forward: bool) -> Set[str]:
    """Transitive descendants (forward=True) or ancestors within `comp`."""
    users = comp.users_map()
    out: Set[str] = set()
    work = [start]
    while work:
        v = work.pop()
        nxt = (users.get(v.name, []) if forward
               else [comp.by_name[o] for o in v.operands if o in comp.by_name])
        for u in nxt:
            if u.name not in out:
                out.add(u.name)
                work.append(u)
    return out


def _mark_callee_comps(module: HloModule, names, out: Set[str]) -> None:
    """Transitively add `names` and every computation they call to `out`."""
    work = list(names)
    while work:
        n = work.pop()
        if n in out:
            continue
        out.add(n)
        callee = module.computations.get(n)
        if callee is None:
            continue
        for i in callee.instructions:
            work.extend(i.called_computations)


_ATOMIC_CONSUMERS = frozenset((
    "conditional", "custom-call", "optimization-barrier",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "send", "outfeed", "dynamic-update-slice",
    "scatter", "fusion", "sort", "reduce", "reduce-window"))


def _parameter_index(instr: HloInstruction) -> Optional[int]:
    m = re.match(r".*\((\d+)\)", instr.raw)
    return int(m.group(1)) if m else None


def _params_at(module: HloModule, comp_names, pidx: int
               ) -> List[Tuple[HloComputation, HloInstruction]]:
    out = []
    for n in comp_names:
        callee = module.computations.get(n)
        if callee is None:
            continue
        for p in callee.instructions:
            if p.opcode == "parameter" and _parameter_index(p) == pidx:
                out.append((callee, p))
    return out


def forward_closure(module: HloModule, comp: HloComputation,
                    instr: HloInstruction
                    ) -> Tuple[Set[Tuple[str, str]], Set[str]]:
    """Everything downstream of `instr`, interprocedurally.

    Returns ``(nodes, comps)`` where `nodes` is a set of
    (computation, instruction) names reachable from `instr`'s value and
    `comps` is a set of computations whose *entire* contents must be treated
    as downstream (bodies the taint enters coarsely). Tuple-element precise
    through ``tuple`` / ``get-tuple-element`` and across ``call`` sites, so
    a halo that only the boundary strips read does not drag the interior
    chunks into the closure.

    A while body's root exits element-precisely to the while's result, but
    the taint does NOT re-enter the body through the back-edge: a collective
    is in flight only from launch until its first consumer fires, and a
    next-iteration consumer of a loop-carried value is (by the loop's own
    dataflow) also an ancestor of the collective's node, so the symmetric
    ancestor check in :func:`independent_compute` already excludes it.
    Re-entering would merge loop instances and mark the next iteration's
    interior chunks — the very work the exchange flies behind — as
    consumers.
    """
    seen: Set[Tuple[str, str, int]] = set()
    nodes: Set[Tuple[str, str]] = set()
    comps: Set[str] = set()
    work: List[Tuple[HloComputation, HloInstruction, int]] = [
        (comp, instr, _WHOLE)]
    users_maps: Dict[str, Dict[str, List[HloInstruction]]] = {}

    def users_of(c: HloComputation) -> Dict[str, List[HloInstruction]]:
        if c.name not in users_maps:
            users_maps[c.name] = c.users_map()
        return users_maps[c.name]

    while work:
        c, v, elem = work.pop()
        key = (c.name, v.name, elem)
        if key in seen:
            continue
        seen.add(key)
        nodes.add((c.name, v.name))
        if v.is_root and not c.is_entry:
            for site_comp, site in module.call_sites(c.name):
                if site.opcode in ("call", "while"):
                    # call result / while loop exit: same tuple element
                    work.append((site_comp, site, elem))
                else:
                    # root of a cond branch etc.: give up precision
                    _mark_callee_comps(module, site.called_computations,
                                       comps)
                    work.append((site_comp, site, _WHOLE))
        for u in users_of(c).get(v.name, []):
            if u.opcode == "tuple":
                if elem != _WHOLE:
                    work.append((c, u, _WHOLE))  # nested: degrade precision
                    continue
                for k, op in enumerate(u.operands):
                    if op == v.name:
                        work.append((c, u, k))
                continue
            if u.opcode == "get-tuple-element":
                idx = u.tuple_index
                if elem == _WHOLE or idx is None or idx == elem:
                    work.append((c, u, _WHOLE))
                continue
            if u.opcode in ("call", "while") and u.called_computations:
                nodes.add((c.name, u.name))
                positions = [k for k, op in enumerate(u.operands)
                             if op == v.name]
                known = True
                for pos in positions:
                    hits = _params_at(module, u.called_computations, pos)
                    if not hits:
                        known = False
                    for callee, p in hits:
                        # the parameter IS the operand, so the taint's tuple
                        # element index survives across the frame boundary
                        work.append((callee, p, elem))
                if u.opcode == "while":
                    work.append((c, u, elem))  # loop result, same element
                elif not known:
                    work.append((c, u, _WHOLE))
                continue
            if u.opcode in _ATOMIC_CONSUMERS:
                _mark_callee_comps(module, u.called_computations, comps)
                work.append((c, u, _WHOLE))
                continue
            work.append((c, u, _WHOLE))
    return nodes, comps


def backward_closure(module: HloModule, comp: HloComputation,
                     instr: HloInstruction
                     ) -> Tuple[Set[Tuple[str, str]], Set[str]]:
    """Everything upstream of `instr`, interprocedurally (coarse: a call or
    while reached through its *result* marks its whole callee closure as
    upstream).

    A while reached through its own body's parameter is different: only the
    loop's init operands are ancestors along that path. Marking the body
    would merge loop instances — every op in the body would become its own
    ancestor, erasing exactly the intra-iteration windows (stage-2 x/y
    stencils vs. the stage-1 exchange) this analysis exists to find.
    """
    # work items: (computation, instruction, mark_callees)
    seen: Set[Tuple[str, str, bool]] = set()
    nodes: Set[Tuple[str, str]] = set()
    comps: Set[str] = set()
    work: List[Tuple[HloComputation, HloInstruction, bool]] = [
        (comp, instr, True)]
    while work:
        c, v, mark = work.pop()
        key = (c.name, v.name, mark)
        if key in seen:
            continue
        seen.add(key)
        nodes.add((c.name, v.name))
        for op in v.operands:
            if op in c.by_name:
                work.append((c, c.by_name[op], True))
        if v.opcode == "parameter" and not c.is_entry:
            pidx = _parameter_index(v)
            for site_comp, site in module.call_sites(c.name):
                if site.opcode == "call" and pidx is not None \
                        and pidx < len(site.operands):
                    op = site.operands[pidx]
                    if op in site_comp.by_name:
                        work.append((site_comp, site_comp.by_name[op], True))
                else:
                    # while/cond carry: init operands feed the parameter;
                    # the body itself is the back-edge — don't mark it
                    work.append((site_comp, site, False))
        elif mark and v.called_computations and v.opcode in _CALLING_OPS:
            _mark_callee_comps(module, v.called_computations, comps)
    return nodes, comps


def independent_compute(module: HloModule, comp: HloComputation,
                        instr: HloInstruction,
                        min_elements: int = 2) -> List[HloInstruction]:
    """Compute instructions anywhere in the module that neither feed `instr`
    nor consume its in-flight result — the work an async scheduler could
    overlap the collective with once XLA inlines the call tree.

    Interprocedural and tuple-element precise on the forward side: a step
    call (or while carry) whose halo outputs feed only the next step's
    boundary strips leaves that step's interior chunks out of the closure,
    so a pipeline-fill exchange correctly finds the first iteration's
    interior compute as its overlap partner, and a loop-carried drain
    exchange finds the peeled step's interior chunks.

    Scalar chaff (loss logging, lr schedules, collective reducers) is
    excluded via `min_elements`."""
    fwd_nodes, fwd_comps = forward_closure(module, comp, instr)
    bwd_nodes, bwd_comps = backward_closure(module, comp, instr)
    related = fwd_nodes | bwd_nodes
    related.add((comp.name, instr.name))
    related_comps = fwd_comps | bwd_comps
    out: List[HloInstruction] = []
    for cname, c in module.computations.items():
        if cname in related_comps:
            continue
        for i in c.instructions:
            if (cname, i.name) in related:
                continue
            if is_compute(module, i) and i.elements() >= min_elements:
                out.append(i)
    return out
