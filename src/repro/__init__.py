"""repro: HDOT-JAX — Hierarchical Domain Over-decomposition with Tasking, adapted to JAX/TPU.

Paper: "HDOT — an Approach Towards Productive Programming of Hybrid Applications"
(Ciesko, Martinez-Ferrer, Penacoba Veigas, Teruel, Beltran; BSC, JPDC 2019).

Public API (lazy — importing `repro` must stay cheap and must NOT touch jax device state):
    repro.config      -- config dataclasses + registry (--arch <id>)
    repro.core        -- the paper's contribution (domain / halo / overlap / reductions)
    repro.models      -- architecture zoo
    repro.kernels     -- Pallas TPU kernels (+ pure-jnp oracles)
"""

__version__ = "1.0.0"

__all__ = ["config", "core", "models", "kernels", "__version__"]


def __getattr__(name):
    if name in __all__:
        import importlib

        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
