"""HDOT core — the paper's contribution as composable JAX modules.

- :mod:`repro.core.domain`            hierarchical domain over-decomposition
- :mod:`repro.core.halo`              halo exchange with interior/boundary overlap
- :mod:`repro.core.overlap`           two-phase vs HDOT communication schedules
- :mod:`repro.core.collective_matmul` ppermute-ring collective matmuls (TP chunk tasks)
- :mod:`repro.core.reduction`         hierarchical task->process reductions
- :mod:`repro.core.stencil`           paper applications (Heat2D / RK3 / HPCCG) on the core
"""

from repro import compat  # noqa: F401  (jax version shims)
from repro.core.domain import (Box, Domain, SubDomain, decompose_grid,
                               halo_cells, interior_boxes)

__all__ = [
    "Box",
    "Domain",
    "SubDomain",
    "decompose_grid",
    "halo_cells",
    "interior_boxes",
]
