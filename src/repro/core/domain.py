"""Hierarchical domain over-decomposition (paper §3.1-3.2).

The paper's central idea: *reuse the process-level partitioning scheme at task
level*. ``decompose_grid`` is that single scheme; ``Domain`` applies it at
process level (mesh shards) and ``Domain.over_decompose`` applies the SAME
function again at task level, producing :class:`SubDomain` lists with
``is_boundary`` checks (paper Code 4) and halo accounting (paper Table 1).

Pure python/numpy — usable before jax initializes, and by the data pipeline,
the stencil apps and the benchmarks alike.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Box:
    """Half-open index box: per-dim [start, stop)."""

    start: Tuple[int, ...]
    stop: Tuple[int, ...]

    def __post_init__(self):
        assert len(self.start) == len(self.stop)
        assert all(a <= b for a, b in zip(self.start, self.stop)), (self.start, self.stop)

    @property
    def ndim(self) -> int:
        return len(self.start)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.start, self.stop))

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    def slices(self) -> Tuple[slice, ...]:
        return tuple(slice(a, b) for a, b in zip(self.start, self.stop))

    def contains(self, other: "Box") -> bool:
        return all(
            sa <= oa and ob <= sb
            for sa, oa, ob, sb in zip(self.start, other.start, other.stop, self.stop)
        )

    def shifted(self, offset: Sequence[int]) -> "Box":
        return Box(
            tuple(a + o for a, o in zip(self.start, offset)),
            tuple(b + o for b, o in zip(self.stop, offset)),
        )


def _split_extent(extent: int, parts: int) -> List[Tuple[int, int]]:
    """Split [0, extent) into `parts` contiguous ranges, remainder spread over
    the leading parts (the classic MPI block distribution)."""
    if parts < 1:
        raise ValueError(f"cannot split extent {extent} into {parts} parts")
    base, rem = divmod(extent, parts)
    out = []
    cur = 0
    for p in range(parts):
        n = base + (1 if p < rem else 0)
        out.append((cur, cur + n))
        cur += n
    assert cur == extent
    return out


def _split_extent_weighted(extent: int, parts: int,
                           weights: Sequence[float]) -> List[Tuple[int, int]]:
    """Split [0, extent) into `parts` contiguous ranges so each part's summed
    per-cell cost approaches total/parts. Cut p is placed at the first cell
    where the cost prefix crosses p/parts of the total, then clamped so every
    part keeps >= 1 cell (when extent >= parts). Guarantees: contiguous
    disjoint cover, monotone cut positions, and
    max part cost <= total/parts + max(weights)."""
    if parts < 1:
        raise ValueError(f"cannot split extent {extent} into {parts} parts")
    w = [float(x) for x in weights]
    if len(w) != extent:
        raise ValueError(
            f"weighted split needs one cost per cell: got {len(w)} weights "
            f"for extent {extent}")
    neg = [x for x in w if x < 0]
    if neg:
        raise ValueError(f"cell weights must be non-negative, got {neg[:3]}")
    total = sum(w)
    if total <= 0.0 or all(x == w[0] for x in w):
        # no signal, or a flat profile: equal-cost cells carry no preference
        # between balanced cuts, so collapse onto the uniform distribution
        # (keeps flat re-measurements from flipping the cut and recompiling)
        return _split_extent(extent, parts)
    prefix = [0.0] * (extent + 1)
    for i, x in enumerate(w):
        prefix[i + 1] = prefix[i] + x
    reserve = 1 if extent >= parts else 0
    cuts = [0]
    for p in range(1, parts):
        target = total * p / parts
        c = cuts[-1]
        while c < extent and prefix[c] < target:
            c += 1
        c = max(c, cuts[-1] + reserve)
        c = min(c, extent - reserve * (parts - p))
        cuts.append(c)
    cuts.append(extent)
    return [(cuts[p], cuts[p + 1]) for p in range(parts)]


def _is_extents(entry, parts: int, extent: int) -> bool:
    """True when `entry` spells explicit per-part extents (len == parts ints
    summing to extent) rather than per-cell costs."""
    try:
        vals = list(entry)
    except TypeError:
        return False
    return (len(vals) == parts
            and all(isinstance(v, int) or (hasattr(v, "is_integer")
                                           and float(v).is_integer())
                    for v in vals)
            and sum(int(v) for v in vals) == extent)


def split_ranges(extent: int, parts: int,
                 weights=None) -> List[Tuple[int, int]]:
    """One dimension of THE partition scheme, with an optional measured-cost
    path. `weights` is one of:

    - ``None`` — the classic uniform block distribution (bit-identical to the
      historical `_split_extent`),
    - explicit per-part extents (`parts` ints summing to `extent`) — a
      canonical cut, used as jit-cache keys by the solvers,
    - per-cell costs (`extent` non-negative floats) — cut so each part's
      summed cost is within max(weights) of the total/parts ideal.
    """
    if weights is None:
        return _split_extent(extent, parts)
    if _is_extents(weights, parts, extent):
        out = []
        cur = 0
        for v in weights:
            n = int(v)
            if n < 0:
                raise ValueError(f"part extents must be >= 0, got {tuple(weights)}")
            out.append((cur, cur + n))
            cur += n
        return out
    return _split_extent_weighted(extent, parts, weights)


def part_extents(extent: int, parts: int, weights=None) -> Tuple[int, ...]:
    """The canonical (hashable) form of one dimension's cut: per-part extents.
    `part_extents(e, p, w)` is idempotent — feeding the result back in as
    `weights` reproduces the same cut — which is what lets the solvers key
    their compiled-program caches on it."""
    return tuple(b - a for a, b in split_ranges(extent, parts, weights))


def _norm_weights(weights, ndim: int):
    """Normalize a per-dim weights spec to a list of ndim entries (None or a
    per-dim sequence)."""
    if weights is None:
        return [None] * ndim
    weights = list(weights)
    if len(weights) != ndim:
        raise ValueError(
            f"weights names {len(weights)} dims but the space is {ndim}-d — "
            f"one entry (or None) per dim required")
    return weights


def decompose_grid(shape: Sequence[int], parts: Sequence[int],
                   weights=None) -> List[Box]:
    """THE partition scheme (used identically at process- and task-level).

    Splits an N-d index space of `shape` into a grid of `parts[i]` blocks per
    dimension, row-major order. Every cell belongs to exactly one box.
    `weights` (optional, one entry per dim) routes a dim through the
    measured-cost cut of :func:`split_ranges`; ``None`` entries stay uniform.
    """
    if len(shape) != len(parts):
        raise ValueError(
            f"shape {tuple(shape)} is {len(shape)}-d but parts "
            f"{tuple(parts)} names {len(parts)} dims — one block count per "
            f"dim required")
    wts = _norm_weights(weights, len(shape))
    per_dim = [split_ranges(e, p, wd)
               for e, p, wd in zip(shape, parts, wts)]

    boxes: List[Box] = []

    def rec(d: int, start: List[int], stop: List[int]):
        if d == len(shape):
            boxes.append(Box(tuple(start), tuple(stop)))
            return
        for a, b in per_dim[d]:
            rec(d + 1, start + [a], stop + [b])

    rec(0, [], [])
    return boxes


def halo_cells(box: Box, global_shape: Sequence[int], width: int,
               dims: Optional[Sequence[int]] = None, periodic: bool = False) -> int:
    """Number of halo cells this box must allocate (paper Table 1 accounting):
    one `width`-deep slab per face that has a neighbor."""
    dims = range(box.ndim) if dims is None else dims
    total = 0
    for d in dims:
        face = box.size // max(box.shape[d], 1)
        lo_neighbor = periodic or box.start[d] > 0
        hi_neighbor = periodic or box.stop[d] < global_shape[d]
        total += width * face * (int(lo_neighbor) + int(hi_neighbor))
    return total


@dataclass(frozen=True)
class SubDomain:
    """A task-level data partition (paper §3.2). Carries its geometric position
    so `is_boundary` can gate communication tasks (paper Code 4's isBoundary)."""

    box: Box                      # in GLOBAL coordinates
    local_box: Box                # in the owning domain's LOCAL coordinates
    domain_box: Box               # the owning process-level domain
    global_shape: Tuple[int, ...]
    index: Tuple[int, ...]        # position in the subdomain grid
    grid: Tuple[int, ...]         # subdomain grid shape

    def is_boundary(self, dim: Optional[int] = None, side: Optional[str] = None) -> bool:
        """True if this subdomain touches the owning *domain's* edge (and thus
        owns an MPI-level communication task in the paper's scheme)."""
        dims = range(self.box.ndim) if dim is None else [dim]
        for d in dims:
            lo = self.box.start[d] == self.domain_box.start[d]
            hi = self.box.stop[d] == self.domain_box.stop[d]
            if side == "lo" and lo:
                return True
            if side == "hi" and hi:
                return True
            if side is None and (lo or hi):
                return True
        return False

    def is_global_boundary(self, dim: Optional[int] = None) -> bool:
        dims = range(self.box.ndim) if dim is None else [dim]
        for d in dims:
            if self.box.start[d] == 0 or self.box.stop[d] == self.global_shape[d]:
                return True
        return False


@dataclass(frozen=True)
class Domain:
    """A process-level data partition (one mesh shard's slice of the global
    problem), created by applying `decompose_grid` at process level."""

    global_shape: Tuple[int, ...]
    box: Box                      # this rank's slice, global coordinates
    rank_index: Tuple[int, ...]   # position in the process grid
    process_grid: Tuple[int, ...]

    # ------------------------------------------------------------- factories
    @staticmethod
    def for_rank(global_shape: Sequence[int], process_grid: Sequence[int],
                 rank: int) -> "Domain":
        boxes = decompose_grid(global_shape, process_grid)
        assert 0 <= rank < len(boxes)
        idx = _unravel(rank, process_grid)
        return Domain(tuple(global_shape), boxes[rank], idx, tuple(process_grid))

    @staticmethod
    def all_ranks(global_shape: Sequence[int], process_grid: Sequence[int]) -> List["Domain"]:
        n = int(math.prod(process_grid))
        return [Domain.for_rank(global_shape, process_grid, r) for r in range(n)]

    # ------------------------------------------------- hierarchical reuse (§3.2)
    def over_decompose(self, sub_grid: Sequence[int]) -> List[SubDomain]:
        """Apply the SAME decomposition scheme one level down: the domain's
        local box is split by `decompose_grid` into task-level subdomains."""
        local_boxes = decompose_grid(self.box.shape, sub_grid)
        subs: List[SubDomain] = []
        for i, lb in enumerate(local_boxes):
            gb = lb.shifted(self.box.start)
            subs.append(
                SubDomain(
                    box=gb,
                    local_box=lb,
                    domain_box=self.box,
                    global_shape=self.global_shape,
                    index=_unravel(i, sub_grid),
                    grid=tuple(sub_grid),
                )
            )
        return subs

    def neighbors(self, periodic: bool = False) -> Dict[Tuple[int, str], Tuple[int, ...]]:
        """rank_index of the neighbor across each face, keyed by (dim, 'lo'|'hi')."""
        out: Dict[Tuple[int, str], Tuple[int, ...]] = {}
        for d in range(len(self.process_grid)):
            for side, delta in (("lo", -1), ("hi", +1)):
                idx = list(self.rank_index)
                idx[d] += delta
                if periodic:
                    idx[d] %= self.process_grid[d]
                elif not (0 <= idx[d] < self.process_grid[d]):
                    continue
                out[(d, side)] = tuple(idx)
        return out

    def halo_cells(self, width: int, dims: Optional[Sequence[int]] = None,
                   periodic: bool = False) -> int:
        return halo_cells(self.box, self.global_shape, width, dims, periodic)


def interior_boxes(shape: Sequence[int], width: int,
                   grid: Sequence[int], weights=None) -> List[Box]:
    """Task-level reuse of :func:`decompose_grid` on the INTERIOR of a local
    block: the cells [width, extent-width) per dim are split into a `grid` of
    chunk boxes (local-block coordinates). This is the 2-D over-decomposition
    the halo machinery feeds its interior chunk tasks from — the same
    partition function that cut the process mesh, one level down; the
    boundary strips (the halo consumers) are exactly the complement.

    `weights` (optional, one entry per dim, sized against the INTERIOR
    extent) produces the measured-cost uneven cut of :func:`split_ranges`;
    ``weights=None`` is bit-identical to the historical uniform grid."""
    inner = [max(0, e - 2 * width) for e in shape]
    shift = (width,) * len(tuple(shape))
    return [b.shifted(shift) for b in decompose_grid(inner, grid, weights)]


def interior_cuts(shape: Sequence[int], width: int, grid: Sequence[int],
                  weights=None) -> Tuple[Tuple[int, ...], ...]:
    """Canonical per-dim part extents of :func:`interior_boxes`' cut — the
    hashable cut descriptor the jitted-solver caches key on, so a rebalance
    that leaves the cut unchanged reuses the compiled program."""
    inner = [max(0, e - 2 * width) for e in shape]
    wts = _norm_weights(weights, len(inner))
    return tuple(part_extents(e, p, wd)
                 for e, p, wd in zip(inner, grid, wts))


def _unravel(i: int, grid: Sequence[int]) -> Tuple[int, ...]:
    out = []
    for g in reversed(list(grid)):
        out.append(i % g)
        i //= g
    return tuple(reversed(out))


# ----------------------------------------------------------- Table 1 analytics
def halo_fraction(global_shape: Sequence[int], process_grid: Sequence[int],
                  width: int = 1) -> Tuple[int, int, float]:
    """Reproduces paper Table 1: total local data, total halo cells, and the
    paper's "% of data in halo" (= halo / data), summed over all ranks."""
    domains = Domain.all_ranks(global_shape, process_grid)
    data = sum(d.box.size for d in domains)
    halo = sum(d.halo_cells(width) for d in domains)
    return data, halo, halo / data
