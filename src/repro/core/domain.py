"""Hierarchical domain over-decomposition (paper §3.1-3.2).

The paper's central idea: *reuse the process-level partitioning scheme at task
level*. ``decompose_grid`` is that single scheme; ``Domain`` applies it at
process level (mesh shards) and ``Domain.over_decompose`` applies the SAME
function again at task level, producing :class:`SubDomain` lists with
``is_boundary`` checks (paper Code 4) and halo accounting (paper Table 1).

Pure python/numpy — usable before jax initializes, and by the data pipeline,
the stencil apps and the benchmarks alike.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Box:
    """Half-open index box: per-dim [start, stop)."""

    start: Tuple[int, ...]
    stop: Tuple[int, ...]

    def __post_init__(self):
        assert len(self.start) == len(self.stop)
        assert all(a <= b for a, b in zip(self.start, self.stop)), (self.start, self.stop)

    @property
    def ndim(self) -> int:
        return len(self.start)

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(b - a for a, b in zip(self.start, self.stop))

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    def slices(self) -> Tuple[slice, ...]:
        return tuple(slice(a, b) for a, b in zip(self.start, self.stop))

    def contains(self, other: "Box") -> bool:
        return all(
            sa <= oa and ob <= sb
            for sa, oa, ob, sb in zip(self.start, other.start, other.stop, self.stop)
        )

    def shifted(self, offset: Sequence[int]) -> "Box":
        return Box(
            tuple(a + o for a, o in zip(self.start, offset)),
            tuple(b + o for b, o in zip(self.stop, offset)),
        )


def _split_extent(extent: int, parts: int) -> List[Tuple[int, int]]:
    """Split [0, extent) into `parts` contiguous ranges, remainder spread over
    the leading parts (the classic MPI block distribution)."""
    if parts < 1:
        raise ValueError(f"cannot split extent {extent} into {parts} parts")
    base, rem = divmod(extent, parts)
    out = []
    cur = 0
    for p in range(parts):
        n = base + (1 if p < rem else 0)
        out.append((cur, cur + n))
        cur += n
    assert cur == extent
    return out


def decompose_grid(shape: Sequence[int], parts: Sequence[int]) -> List[Box]:
    """THE partition scheme (used identically at process- and task-level).

    Splits an N-d index space of `shape` into a grid of `parts[i]` blocks per
    dimension, row-major order. Every cell belongs to exactly one box.
    """
    if len(shape) != len(parts):
        raise ValueError(
            f"shape {tuple(shape)} is {len(shape)}-d but parts "
            f"{tuple(parts)} names {len(parts)} dims — one block count per "
            f"dim required")
    per_dim = [_split_extent(e, p) for e, p in zip(shape, parts)]

    boxes: List[Box] = []

    def rec(d: int, start: List[int], stop: List[int]):
        if d == len(shape):
            boxes.append(Box(tuple(start), tuple(stop)))
            return
        for a, b in per_dim[d]:
            rec(d + 1, start + [a], stop + [b])

    rec(0, [], [])
    return boxes


def halo_cells(box: Box, global_shape: Sequence[int], width: int,
               dims: Optional[Sequence[int]] = None, periodic: bool = False) -> int:
    """Number of halo cells this box must allocate (paper Table 1 accounting):
    one `width`-deep slab per face that has a neighbor."""
    dims = range(box.ndim) if dims is None else dims
    total = 0
    for d in dims:
        face = box.size // max(box.shape[d], 1)
        lo_neighbor = periodic or box.start[d] > 0
        hi_neighbor = periodic or box.stop[d] < global_shape[d]
        total += width * face * (int(lo_neighbor) + int(hi_neighbor))
    return total


@dataclass(frozen=True)
class SubDomain:
    """A task-level data partition (paper §3.2). Carries its geometric position
    so `is_boundary` can gate communication tasks (paper Code 4's isBoundary)."""

    box: Box                      # in GLOBAL coordinates
    local_box: Box                # in the owning domain's LOCAL coordinates
    domain_box: Box               # the owning process-level domain
    global_shape: Tuple[int, ...]
    index: Tuple[int, ...]        # position in the subdomain grid
    grid: Tuple[int, ...]         # subdomain grid shape

    def is_boundary(self, dim: Optional[int] = None, side: Optional[str] = None) -> bool:
        """True if this subdomain touches the owning *domain's* edge (and thus
        owns an MPI-level communication task in the paper's scheme)."""
        dims = range(self.box.ndim) if dim is None else [dim]
        for d in dims:
            lo = self.box.start[d] == self.domain_box.start[d]
            hi = self.box.stop[d] == self.domain_box.stop[d]
            if side == "lo" and lo:
                return True
            if side == "hi" and hi:
                return True
            if side is None and (lo or hi):
                return True
        return False

    def is_global_boundary(self, dim: Optional[int] = None) -> bool:
        dims = range(self.box.ndim) if dim is None else [dim]
        for d in dims:
            if self.box.start[d] == 0 or self.box.stop[d] == self.global_shape[d]:
                return True
        return False


@dataclass(frozen=True)
class Domain:
    """A process-level data partition (one mesh shard's slice of the global
    problem), created by applying `decompose_grid` at process level."""

    global_shape: Tuple[int, ...]
    box: Box                      # this rank's slice, global coordinates
    rank_index: Tuple[int, ...]   # position in the process grid
    process_grid: Tuple[int, ...]

    # ------------------------------------------------------------- factories
    @staticmethod
    def for_rank(global_shape: Sequence[int], process_grid: Sequence[int],
                 rank: int) -> "Domain":
        boxes = decompose_grid(global_shape, process_grid)
        assert 0 <= rank < len(boxes)
        idx = _unravel(rank, process_grid)
        return Domain(tuple(global_shape), boxes[rank], idx, tuple(process_grid))

    @staticmethod
    def all_ranks(global_shape: Sequence[int], process_grid: Sequence[int]) -> List["Domain"]:
        n = int(math.prod(process_grid))
        return [Domain.for_rank(global_shape, process_grid, r) for r in range(n)]

    # ------------------------------------------------- hierarchical reuse (§3.2)
    def over_decompose(self, sub_grid: Sequence[int]) -> List[SubDomain]:
        """Apply the SAME decomposition scheme one level down: the domain's
        local box is split by `decompose_grid` into task-level subdomains."""
        local_boxes = decompose_grid(self.box.shape, sub_grid)
        subs: List[SubDomain] = []
        for i, lb in enumerate(local_boxes):
            gb = lb.shifted(self.box.start)
            subs.append(
                SubDomain(
                    box=gb,
                    local_box=lb,
                    domain_box=self.box,
                    global_shape=self.global_shape,
                    index=_unravel(i, sub_grid),
                    grid=tuple(sub_grid),
                )
            )
        return subs

    def neighbors(self, periodic: bool = False) -> Dict[Tuple[int, str], Tuple[int, ...]]:
        """rank_index of the neighbor across each face, keyed by (dim, 'lo'|'hi')."""
        out: Dict[Tuple[int, str], Tuple[int, ...]] = {}
        for d in range(len(self.process_grid)):
            for side, delta in (("lo", -1), ("hi", +1)):
                idx = list(self.rank_index)
                idx[d] += delta
                if periodic:
                    idx[d] %= self.process_grid[d]
                elif not (0 <= idx[d] < self.process_grid[d]):
                    continue
                out[(d, side)] = tuple(idx)
        return out

    def halo_cells(self, width: int, dims: Optional[Sequence[int]] = None,
                   periodic: bool = False) -> int:
        return halo_cells(self.box, self.global_shape, width, dims, periodic)


def interior_boxes(shape: Sequence[int], width: int,
                   grid: Sequence[int]) -> List[Box]:
    """Task-level reuse of :func:`decompose_grid` on the INTERIOR of a local
    block: the cells [width, extent-width) per dim are split into a `grid` of
    chunk boxes (local-block coordinates). This is the 2-D over-decomposition
    the halo machinery feeds its interior chunk tasks from — the same
    partition function that cut the process mesh, one level down; the
    boundary strips (the halo consumers) are exactly the complement."""
    inner = [max(0, e - 2 * width) for e in shape]
    shift = (width,) * len(tuple(shape))
    return [b.shifted(shift) for b in decompose_grid(inner, grid)]


def _unravel(i: int, grid: Sequence[int]) -> Tuple[int, ...]:
    out = []
    for g in reversed(list(grid)):
        out.append(i % g)
        i //= g
    return tuple(reversed(out))


# ----------------------------------------------------------- Table 1 analytics
def halo_fraction(global_shape: Sequence[int], process_grid: Sequence[int],
                  width: int = 1) -> Tuple[int, int, float]:
    """Reproduces paper Table 1: total local data, total halo cells, and the
    paper's "% of data in halo" (= halo / data), summed over all ranks."""
    domains = Domain.all_ranks(global_shape, process_grid)
    data = sum(d.box.size for d in domains)
    halo = sum(d.halo_cells(width) for d in domains)
    return data, halo, halo / data
