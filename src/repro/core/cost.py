"""Measured-cost model for dynamic re-partitioning (paper §3.2's load-balance
claim, made adaptive).

HDOT's interior chunk grid absorbs imbalance only if the cut tracks where the
time actually goes. This module is the measurement half: per-chunk wall-clock
is recorded OUTSIDE jit (timing inside a compiled program is meaningless), an
EMA smooths transient noise, and :meth:`CostModel.weights_along` turns the
chunk EMAs back into per-dim per-cell cost profiles — exactly the `weights=`
input :func:`repro.core.domain.split_ranges` cuts on. Pure python: usable by
the in-process re-cut driver and the multi-host straggler drill alike.
"""
from __future__ import annotations

import itertools
from typing import Dict, Optional, Sequence, Tuple


class CostModel:
    """Per-key EMA of measured cost, normalized per cell.

    Keys are arbitrary hashables — the re-cut driver uses interior-chunk grid
    indices ``(i, j, ...)``, the straggler drill uses ``(worker_id,)``.
    Normalizing by `cells` before the EMA keeps the estimate stable across
    re-cuts that change a chunk's size: what we track is the *rate* (seconds
    per cell), which is a property of the owner, not of the current cut.
    """

    def __init__(self, alpha: float = 0.5):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._ema: Dict[object, float] = {}
        self._count: Dict[object, int] = {}

    def record(self, key, seconds: float, cells: int = 1) -> float:
        """Fold one wall-clock observation into the key's per-cell EMA and
        return the updated estimate."""
        if seconds < 0:
            raise ValueError(f"seconds must be >= 0, got {seconds}")
        per_cell = seconds / max(int(cells), 1)
        prev = self._ema.get(key)
        cur = per_cell if prev is None else (
            self.alpha * per_cell + (1.0 - self.alpha) * prev)
        self._ema[key] = cur
        self._count[key] = self._count.get(key, 0) + 1
        return cur

    def ema(self, key, default: Optional[float] = None) -> Optional[float]:
        return self._ema.get(key, default)

    def observations(self, key) -> int:
        return self._count.get(key, 0)

    def __len__(self) -> int:
        return len(self._ema)

    def mean_rate(self) -> float:
        """Mean per-cell rate over every recorded key (the prior used for
        chunks that have not been measured yet)."""
        if not self._ema:
            return 1.0
        return sum(self._ema.values()) / len(self._ema)

    def weights_along(self, per_dim_ranges: Sequence[Sequence[Tuple[int, int]]]
                      ) -> Tuple[Tuple[float, ...], ...]:
        """Marginalize the chunk EMAs into per-dim per-cell cost profiles.

        `per_dim_ranges` is the CURRENT cut: for each dim, the list of
        (start, stop) chunk ranges, so chunk ``(i0, ..., iN)`` covers
        ``per_dim_ranges[d][id]`` along dim d and its EMA is looked up under
        that grid-index key. Each dim's profile assigns every cell the mean
        per-cell rate of the chunks whose range covers it (averaging over the
        other dims); unmeasured chunks fall back to :meth:`mean_rate`. The
        result plugs straight into ``interior_boxes(..., weights=...)`` for
        the next cut."""
        prior = self.mean_rate()
        ndim = len(per_dim_ranges)
        extents = [max(b for _, b in rng) if rng else 0
                   for rng in per_dim_ranges]
        acc = [[0.0] * e for e in extents]
        cnt = [[0] * e for e in extents]
        for idx in itertools.product(*[range(len(r)) for r in per_dim_ranges]):
            rate = self._ema.get(tuple(idx), prior)
            for d in range(ndim):
                a, b = per_dim_ranges[d][idx[d]]
                for c in range(a, b):
                    acc[d][c] += rate
                    cnt[d][c] += 1
        return tuple(
            tuple(acc[d][c] / cnt[d][c] if cnt[d][c] else prior
                  for c in range(extents[d]))
            for d in range(ndim))
