"""Hierarchical task->process reductions (paper §3.3, Code 5).

The paper computes reductions at two levels: concurrent tasks privately reduce
subdomain partials (OmpSs-2 `reduction(MAX:rlocal)`), then one communication
task performs the process-level `MPI_Allreduce`. The TPU analogue:

  task level     = per-subdomain partials reduced locally (tree reduction of
                   chunk results inside the shard)
  process level  = `lax.psum` / `lax.pmax` over mesh axes, optionally staged
                   hierarchically (reduce-scatter in-pod -> all-reduce
                   cross-pod -> all-gather in-pod) so the slow cross-pod hop
                   carries 1/pod_size of the bytes.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
from jax import lax

AxisNames = Union[str, Sequence[str]]

_OPS = {
    "sum": (jnp.add, lax.psum),
    "max": (jnp.maximum, lax.pmax),
    "min": (jnp.minimum, lax.pmin),
}


def task_reduce(partials: Sequence[jax.Array], op: str = "sum") -> jax.Array:
    """Tree-reduce task-level (subdomain) partials inside one shard.

    Mirrors OmpSs-2's `reduction` clause: each subdomain task produced a
    private partial; this combines them in O(log n) dataflow depth so the
    combine itself exposes no serialization."""
    combine, _ = _OPS[op]
    items = list(partials)
    if not items:
        # bare asserts vanish under `python -O`; this is a caller bug that
        # must surface loudly on the reduction hot path
        raise ValueError("task_reduce needs at least one partial")
    while len(items) > 1:
        nxt = []
        for i in range(0, len(items) - 1, 2):
            nxt.append(combine(items[i], items[i + 1]))
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    return items[0]


def process_allreduce(x: jax.Array, axes: AxisNames, op: str = "sum") -> jax.Array:
    """Process-level collective (the paper's MPI_Allreduce) over mesh axes."""
    _, coll = _OPS[op]
    return coll(x, axes)


def hdot_reduce(partials: Sequence[jax.Array], axes: AxisNames,
                op: str = "sum") -> jax.Array:
    """Full paper pattern: task-level tree reduce -> process-level allreduce."""
    return process_allreduce(task_reduce(partials, op), axes, op)


def hierarchical_allreduce(x: jax.Array, inner_axis: str,
                           outer_axis: Optional[str] = None,
                           scatter_dim: int = 0,
                           compress: Optional[Callable] = None,
                           decompress: Optional[Callable] = None) -> jax.Array:
    """Bandwidth-staged allreduce for multi-pod meshes.

    reduce-scatter over `inner_axis` (fast in-pod ICI), then all-reduce over
    `outer_axis` (slow cross-pod hop, optionally compressed), then all-gather
    over `inner_axis`. Equivalent to psum over both axes; cross-pod bytes are
    reduced by  inner_size x (x compression ratio).

    `compress/decompress` wrap ONLY the cross-pod hop (e.g. int8 error-feedback
    from repro.optim.compression)."""
    if x.shape[scatter_dim] % lax.axis_size(inner_axis) != 0:
        # fall back: shape not tileable -> plain fused psum (still correct)
        axes = (inner_axis,) if outer_axis is None else (inner_axis, outer_axis)
        return lax.psum(x, axes)
    part = lax.psum_scatter(x, inner_axis, scatter_dimension=scatter_dim, tiled=True)
    if outer_axis is not None:
        if compress is not None:
            payload = compress(part)
            payload = jax.tree.map(lambda t: lax.psum(t, outer_axis), payload)
            part = decompress(payload)
        else:
            part = lax.psum(part, outer_axis)
    return lax.all_gather(part, inner_axis, axis=scatter_dim, tiled=True)
