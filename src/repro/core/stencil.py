"""The paper's applications (§4) rebuilt on the HDOT core: Heat2D, a
CREAMS-like RK3 multi-direction stencil, and HPCCG's preconditioned CG.

Each app exposes the SAME solver under the two schedules
(``mode='two_phase'`` = paper's MPI+OpenMP baseline, ``mode='hdot'``), so the
benchmarks can measure the overlap delta directly, and tests can assert the
schedules are numerically identical.

All solvers are shard_map'd over the process-level decomposition — one mesh
axis (the paper's slabs), a 2-D (rows x cols) grid mesh, or (HPCCG) a full
3-D (x, y, z) mesh — and over-decompose each shard into task-level
subdomains (``subdomains=`` — the paper's grainsize knob) for residual
reductions and boundary/interior splits.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat  # noqa: F401  (jax version shims)
from repro.core.domain import part_extents
from repro.core.halo import (_norm_subn, exchange_halo, halo_scan_nd,
                             multi_dim_stencil, pad_with_halo,
                             stencil_apply_nd, stencil_with_halo_nd)
from repro.core.reduction import hdot_reduce, task_reduce

_STR_AXES_WARNED: set = set()


def normalize_mesh_axes(mesh_axes, solver: str,
                        arities: Tuple[int, ...]) -> Tuple[str, ...]:
    """THE solver mesh-topology contract: every solver takes
    ``mesh_axes: tuple[str, ...]`` — one mesh axis name per decomposed grid
    dim, arity selecting the topology (1 = the paper's slabs, 2/3 = grid
    meshes). A bare string is accepted as a deprecated 1-axis spelling and
    coerced (with a once-per-process note); anything else out of contract
    raises a ValueError naming the solver and its accepted arities."""
    if isinstance(mesh_axes, str):
        if solver not in _STR_AXES_WARNED:
            _STR_AXES_WARNED.add(solver)
            warnings.warn(
                f"{solver}: passing mesh_axes as a bare axis name is "
                f"deprecated; pass a tuple, e.g. ({mesh_axes!r},)",
                DeprecationWarning, stacklevel=3)
        axes = (mesh_axes,)
    else:
        try:
            axes = tuple(mesh_axes)
        except TypeError:
            raise ValueError(
                f"{solver}: mesh_axes must be a tuple of mesh axis names, "
                f"got {mesh_axes!r}") from None
    if not all(isinstance(a, str) for a in axes):
        raise ValueError(
            f"{solver}: mesh_axes entries must be mesh axis names (str), "
            f"got {axes!r}")
    if len(axes) not in arities:
        want = " or ".join(str(a) for a in arities)
        raise ValueError(
            f"{solver}: mesh_axes takes {want} axis name(s), got "
            f"{len(axes)}: {axes!r}")
    if len(set(axes)) != len(axes):
        raise ValueError(f"{solver}: mesh_axes repeats an axis: {axes!r}")
    return axes


# =============================================================== Heat2D (§4.1)
def _jacobi_stencil(padded: jax.Array, dim: int = 0) -> jax.Array:
    """5-point Jacobi update. `padded` has 1 ghost row on both ends of dim 0;
    dim 1 uses Dirichlet-0 global boundaries (zero pad)."""
    assert dim == 0
    p = jnp.pad(padded, ((0, 0), (1, 1)))
    return 0.25 * (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:])


def _jacobi_stencil_2d(padded: jax.Array) -> jax.Array:
    """5-point Jacobi on a block padded by 1 ghost cell on BOTH dims (the
    2-D-mesh contract; corner ghosts are dead — the star never reads them)."""
    return 0.25 * (padded[:-2, 1:-1] + padded[2:, 1:-1]
                   + padded[1:-1, :-2] + padded[1:-1, 2:])


def _heat2d_residual(axes, subdomains: int):
    """paper-Code-5 residual: task-level subdomain MAX partials -> allreduce
    (`axes` may be one mesh axis name or the (rows, cols) pair)."""
    def residual(u_new, u):
        diff = jnp.abs(u_new - u)
        chunks = jnp.array_split(diff, subdomains, axis=0)
        partials = [jnp.max(c) for c in chunks]
        return hdot_reduce(partials, axes, op="max")
    return residual


@functools.lru_cache(maxsize=128)
def _heat2d_solver(mesh, axes, iters: int, mode: str, subdomains, cuts=None):
    """Cached jitted solver — (mesh, config, cut) -> compiled fn. Without
    this, every heat2d_solve call re-traced and re-compiled, so repeated
    calls (and the benchmark timing loops) measured XLA compile time instead
    of solver throughput. `cuts` is the canonical per-dim chunk-extents tuple
    from a measured-cost re-partition (None = uniform): keying the cache on
    it means a rebalance recompiles ONLY when the cut actually changes and an
    unchanged cut reuses the compiled program."""
    axes = normalize_mesh_axes(axes, "heat2d_solve", (1, 2))
    subs = _norm_subn(subdomains, len(axes))
    hs_axes = tuple((a, d) for d, a in enumerate(axes))
    n_chunks = 1
    for s in subs:
        n_chunks *= s
    stencil_fn = _jacobi_stencil_2d if len(axes) == 2 else _jacobi_stencil

    def local(u):
        return halo_scan_nd(
            u, stencil_fn, hs_axes, width=1, steps=iters, periodic=False,
            mode=mode, subdomains=subs,
            step_out_fn=_heat2d_residual(axes, n_chunks), weights=cuts)

    spec = P(*axes) if len(axes) == 2 else P(axes[0], None)
    f = jax.shard_map(local, mesh=mesh, in_specs=spec,
                      out_specs=(spec, P()))
    return jax.jit(f)


def _heat2d_cuts(global_shape, mesh, axes, subdomains, chunk_weights):
    """Canonicalize per-dim measured chunk costs into the hashable cut tuple
    the jitted-solver cache keys on. Each entry of `chunk_weights` is None
    (uniform), per-cell costs over the LOCAL shard's interior extent, or
    explicit chunk extents. Returns None when the resolved cut IS the uniform
    one, so a rebalance that lands back on uniform hits the same compiled
    program as a plain solve."""
    if chunk_weights is None:
        return None
    from repro.core.domain import _is_extents

    w = 1
    subs = _norm_subn(subdomains, len(axes))
    entries = list(chunk_weights)
    if len(entries) != len(axes):
        raise ValueError(
            f"heat2d_solve: chunk_weights names {len(entries)} dims but the "
            f"decomposition is {len(axes)}-dimensional")
    out = []
    is_default = []
    for d, (name, k, entry) in enumerate(zip(axes, subs, entries)):
        n_local = global_shape[d] // mesh.shape[name]
        inner = max(0, n_local - 2 * w)
        kd = max(1, min(k, inner // (2 * w)))  # the clamped default count
        if entry is None:
            out.append(None)
            is_default.append(True)
            continue
        entry = tuple(entry)
        # len == interior extent reads as per-cell costs (uniform integer
        # costs sum to the extent and would otherwise masquerade as a grid
        # of 1-cell chunk extents); any other length must be explicit extents
        if len(entry) != inner and _is_extents(entry, len(entry), inner):
            out.append(tuple(int(v) for v in entry))
        else:
            out.append(part_extents(inner, kd, entry))
        is_default.append(out[-1] == part_extents(inner, kd, None))
    # a re-cut that lands back on the default uniform grid IS no cut:
    # collapse onto the unweighted cache entry (no recompile)
    if all(is_default):
        return None
    return tuple(out)


def heat2d_solve(u0: jax.Array, mesh, mesh_axes, iters: int,
                 mode: str = "hdot", subdomains=4,
                 chunk_weights=None) -> Tuple[jax.Array, jax.Array]:
    """Run `iters` sweeps; returns (final grid, residual history).

    u0 is the GLOBAL grid; sharding happens here — process-level
    decomposition == mesh. `mesh_axes` is the unified solver topology
    contract (one mesh axis name per decomposed grid dim):

      * ``(axis,)`` — the paper's horizontal MPI slabs (1-D, dim 0),
      * ``(rows_axis, cols_axis)`` — true 2-D block decomposition over both
        grid dims via :func:`halo_scan_nd` (corner-free pipelining).

    The sweep loop is double-buffered either way: sweep k+1's halo
    ppermute(s) depart while sweep k's interior chunk tasks compute (hdot
    mode), and the drain sweep is peeled.

    `chunk_weights` (per decomposed dim: None, per-cell measured costs over
    the local interior, or explicit chunk extents) re-cuts the interior chunk
    grid by measured cost — the dynamic load-balancing path. It is
    canonicalized to chunk extents BEFORE the solver cache, so re-measuring
    identical costs (or an unchanged cut) never recompiles."""
    axes = normalize_mesh_axes(mesh_axes, "heat2d_solve", (1, 2))
    if isinstance(subdomains, list):
        subdomains = tuple(subdomains)
    cuts = _heat2d_cuts(u0.shape, mesh, axes, subdomains, chunk_weights)
    return _heat2d_solver(mesh, axes, iters, mode, subdomains, cuts)(u0)


def heat2d_init(nx: int, ny: int, dtype=jnp.float32) -> jax.Array:
    """Hot square blob in the middle, Dirichlet-0 edges."""
    u = jnp.zeros((nx, ny), dtype)
    cx, cy, w = nx // 2, ny // 2, max(1, nx // 8)
    return u.at[cx - w:cx + w, cy - w:cy + w].set(1.0)


# ========================================== CREAMS-like RK3 stencil (§4.2)
# 8th-order central second-derivative coefficients (halo width 4 == CREAMS Nh).
_C8 = jnp.array([-1 / 560, 8 / 315, -1 / 5, 8 / 5, -205 / 72, 8 / 5, -1 / 5, 8 / 315, -1 / 560])
# classic Williamson low-storage RK3 coefficients
_RK3_A = (0.0, -5 / 9, -153 / 128)
_RK3_B = (1 / 3, 15 / 16, 8 / 15)


def _diff2_dir(padded: jax.Array, dim: int) -> jax.Array:
    """8th-order d2/dx_dim^2 over a block padded by 4 ghosts along `dim`."""
    n = padded.shape[dim] - 8
    out = None
    for j, c in enumerate(_C8.tolist()):
        sl = lax.slice_in_dim(padded, j, j + n, axis=dim)
        out = c * sl if out is None else out + c * sl
    return out


def rk3_rhs(v: jax.Array, axis_name, mode: str,
            nu: float = 0.05) -> jax.Array:
    """Direction-split diffusion RHS (stands in for euler_LLF_x/y/z): the three
    per-direction stencils are independent tasks (paper Figure 5). `axis_name`
    is one mesh axis (z decomposed) or a (y_axis, z_axis) pair — each
    direction's stencil only ever needs its OWN axis's halo (direction-split
    stencils have no cross-dim couplings), so a 2-D mesh needs no corner
    messages at all."""
    if isinstance(axis_name, tuple):
        ay, az = axis_name
        decomp = [(0, None), (1, ay), (2, az)]
    else:
        decomp = [(0, None), (1, None), (2, axis_name)]
    return nu * multi_dim_stencil(v, _diff2_dir, decomp, width=4,
                                  periodic=True, mode=mode)


def _rk3_rhs_with_halo(v: jax.Array, lo: jax.Array, hi: jax.Array,
                       nu: float = 0.05, subdomains: int = 4) -> jax.Array:
    """RHS with z-halos already in hand (pipelined schedule): the x/y stencils
    are multi_dim_stencil's local-pad tasks, the z stencil consumes the
    carried halos — no exchange on this stage's critical path."""
    xy = multi_dim_stencil(v, _diff2_dir, [(0, None), (1, None)], width=4,
                           periodic=True)
    z = stencil_with_halo_nd(v, [(lo, hi)], functools.partial(_diff2_dir, dim=2),
                             width=4, dims=(2,), subdomains=(subdomains,))
    return nu * (xy + z)


def _rk3_rhs_with_halo_2d(v: jax.Array, hy, hz, nu: float = 0.05,
                          subdomains: int = 4) -> jax.Array:
    """RHS with BOTH mesh axes' halos already in hand ((y, z) grid mesh):
    the x stencil is a local-pad task; the y and z stencils each consume
    their own carried halo pair — neither exchange sits on this stage's
    critical path, and the per-direction interior chunks are the independent
    work both ppermute pairs hide behind."""
    x = multi_dim_stencil(v, _diff2_dir, [(0, None)], width=4, periodic=True)
    y = stencil_with_halo_nd(v, [hy], functools.partial(_diff2_dir, dim=1),
                             width=4, dims=(1,), subdomains=(subdomains,))
    z = stencil_with_halo_nd(v, [hz], functools.partial(_diff2_dir, dim=2),
                             width=4, dims=(2,), subdomains=(subdomains,))
    return nu * (x + y + z)


def rk3_local_step(v: jax.Array, axis_name: Optional[str], dt: float,
                   mode: str) -> jax.Array:
    """One 3-stage low-storage RK step (paper Code 8's rk loop): each stage is
    data-prep -> per-direction stencils -> update -> halo comm, with the HDOT
    schedule overlapping the z-direction halo with the x/y stencil tasks."""
    s = jnp.zeros_like(v)
    for a, b in zip(_RK3_A, _RK3_B):
        rhs = rk3_rhs(v, axis_name, mode)
        s = a * s + dt * rhs
        v = v + b * s
    return v


def rk3_local_step_pipelined(v: jax.Array, lo: jax.Array, hi: jax.Array,
                             axis_name: str, dt: float,
                             subdomains: int = 4, exchange_last: bool = True
                             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """RK3 step with z-halos carried across stages: each stage consumes the
    halos exchanged at the END of the previous stage, and launches the next
    exchange the moment its `v` update lands — so every z ppermute flies
    behind the next stage's x/y stencils and interior z chunks (the
    double-buffered analogue of Code 8's comm task). `exchange_last=False`
    peels the drain: the solve's final stage feeds no consumer, so its
    exchange would be a dead width-4 ppermute pair."""
    s = jnp.zeros_like(v)
    n_stages = len(_RK3_A)
    for i, (a, b) in enumerate(zip(_RK3_A, _RK3_B)):
        rhs = _rk3_rhs_with_halo(v, lo, hi, subdomains=subdomains)
        s = a * s + dt * rhs
        v = v + b * s
        if exchange_last or i < n_stages - 1:
            lo, hi = exchange_halo(v, axis_name, width=4, dim=2, periodic=True)
    return v, lo, hi


def rk3_local_step_pipelined_2d(v: jax.Array, hy, hz, ay: str, az: str,
                                dt: float, subdomains: int = 4,
                                exchange_last: bool = True):
    """RK3 step on a (y, z) grid mesh with BOTH axes' halos carried across
    stages: each stage consumes the pairs exchanged at the END of the
    previous stage and launches the next y AND z exchanges the moment its
    `v` update lands — so every ppermute pair flies behind the next stage's
    x stencil and the y/z interior chunks. `exchange_last=False` peels the
    drain (the solve's final stage feeds no consumer — two dead width-4
    pairs saved per solve)."""
    s = jnp.zeros_like(v)
    n_stages = len(_RK3_A)
    for i, (a, b) in enumerate(zip(_RK3_A, _RK3_B)):
        rhs = _rk3_rhs_with_halo_2d(v, hy, hz, subdomains=subdomains)
        s = a * s + dt * rhs
        v = v + b * s
        if exchange_last or i < n_stages - 1:
            hy = exchange_halo(v, ay, width=4, dim=1, periodic=True)
            hz = exchange_halo(v, az, width=4, dim=2, periodic=True)
    return v, hy, hz


@functools.lru_cache(maxsize=128)
def _rk3_solver(mesh, axes, steps: int, dt: float, mode: str):
    axes = normalize_mesh_axes(axes, "rk3_solve", (1, 2))
    two_d = len(axes) == 2
    ay, az = axes if two_d else (None, None)
    axis_name = axes if two_d else axes[0]

    def local(v):
        if (two_d and mode == "hdot" and v.shape[1] >= 16
                and v.shape[2] >= 16 and steps > 0):
            hy = exchange_halo(v, ay, width=4, dim=1, periodic=True)
            hz = exchange_halo(v, az, width=4, dim=2, periodic=True)

            def body(carry, _):
                v, hy, hz = carry
                return rk3_local_step_pipelined_2d(v, hy, hz, ay, az, dt), None

            # drain peeled: the last step's last-stage exchanges are dead
            (v, hy, hz), _ = lax.scan(body, (v, hy, hz), None,
                                      length=steps - 1)
            v, _, _ = rk3_local_step_pipelined_2d(v, hy, hz, ay, az, dt,
                                                  exchange_last=False)
            return v

        if not two_d and mode == "hdot" and v.shape[2] >= 16 and steps > 0:
            lo, hi = exchange_halo(v, axis_name, width=4, dim=2,
                                   periodic=True)  # pipeline fill

            def body(carry, _):
                return rk3_local_step_pipelined(*carry, axis_name, dt), None

            # drain peeled: the last step's last-stage exchange is dead
            (v, lo, hi), _ = lax.scan(body, (v, lo, hi), None,
                                      length=steps - 1)
            v, _, _ = rk3_local_step_pipelined(v, lo, hi, axis_name, dt,
                                               exchange_last=False)
            return v

        def body(v, _):
            return rk3_local_step(v, axis_name, dt, mode), None
        v, _ = lax.scan(body, v, None, length=steps)
        return v

    spec = P(None, ay, az) if two_d else P(None, None, axis_name)
    f = jax.shard_map(local, mesh=mesh, in_specs=spec, out_specs=spec)
    return jax.jit(f)


def rk3_solve(v0: jax.Array, mesh, mesh_axes, steps: int, dt: float = 0.05,
              mode: str = "hdot") -> jax.Array:
    """Run `steps` RK3 steps. `mesh_axes` is the unified solver topology
    contract: ``(z_axis,)`` — the paper's z-decomposed slabs — or a
    ``(y_axis, z_axis)`` pair — true 2-D (y, z) grid-mesh decomposition with
    stage-carried halos on BOTH axes (each direction-split stencil consumes
    only its own axis's pair, so the 2-D mesh needs no corner messages)."""
    axes = normalize_mesh_axes(mesh_axes, "rk3_solve", (1, 2))
    return _rk3_solver(mesh, axes, steps, dt, mode)(v0)


# ============================================================ HPCCG CG (§4.3)
def _sum27(q: jax.Array) -> jax.Array:
    """HPCCG's 27-point operator (diag=26, off-diag=-1) on a fully padded
    (nx+2, ny+2, nz+2) block; returns the (nx, ny, nz) interior."""
    nx, ny, nz = q.shape[0] - 2, q.shape[1] - 2, q.shape[2] - 2
    acc = 0.0
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                sl = q[1 + dx:nx + 1 + dx, 1 + dy:ny + 1 + dy,
                       1 + dz:nz + 1 + dz]
                if dx == dy == dz == 0:
                    acc = acc + 26.0 * sl
                else:
                    acc = acc - sl
    return acc


def _stencil27_matvec(p: jax.Array, axis_name: Optional[str], mode: str,
                      halos: Optional[Tuple[jax.Array, jax.Array]] = None,
                      subdomains: int = 4) -> jax.Array:
    """y = A p for the 27-point operator on a 3-D grid stacked along z
    (dim 2), halo width 1. Only z is decomposed, so the exchanged plane
    carries all in-plane diagonals (corner-free exchange).

    `halos=(lo, hi)` supplies pre-exchanged z-planes (the pipelined CG
    schedule: the exchange for iteration k+1's matvec departs when p_{k+1} is
    formed, and only the boundary-plane tasks here consume it)."""

    def per_z(padded: jax.Array, dim: int) -> jax.Array:
        assert dim == 2
        # pad x,y locally with zeros (global Dirichlet)
        return _sum27(jnp.pad(padded, ((1, 1), (1, 1), (0, 0))))

    fn = functools.partial(per_z, dim=2)
    if halos is not None:
        return stencil_with_halo_nd(p, [halos], fn, width=1, dims=(2,),
                                    subdomains=(subdomains,))
    if axis_name is None:
        pads = [(0, 0), (0, 0), (1, 1)]
        return fn(jnp.pad(p, pads))
    return stencil_apply_nd(p, fn, ((axis_name, 2),), width=1,
                            periodic=False, mode=mode, subdomains=(4,))


def _chain_fn27(dims: Tuple[int, ...]):
    """27-point apply for a block that ALREADY carries ghosts on every dim in
    `dims` (plus width-1 padding on the last dim supplied by the caller);
    the remaining dims are padded locally with zeros (global Dirichlet)."""
    pads = tuple((0, 0) if d in dims else (1, 1) for d in range(3))

    def fn(block: jax.Array) -> jax.Array:
        if any(p != (0, 0) for p in pads):
            block = jnp.pad(block, pads)
        return _sum27(block)

    return fn


def _exchange_chain(p: jax.Array, axes: Tuple[str, ...],
                    dims: Tuple[int, ...]
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sequential face-message exchange for an N-D process mesh (the MPI
    ordered-exchange trick, chained): pad every decomposed dim but the last
    IN ORDER — each pad ships the PREVIOUSLY padded block, so its face
    messages carry the earlier dims' edge values from the diagonal ranks via
    the shared neighbors — then exchange the LAST dim's faces of the fully
    padded block. The final halo planes thus carry every (multi-)corner
    coupling of the 27-point operator with face ppermutes only: one pair per
    axis, no corner messages. Returns (p_padded, lo_last, hi_last)."""
    for a, d in zip(axes[:-1], dims[:-1]):
        p = pad_with_halo(p, a, 1, dim=d)
    lo, hi = exchange_halo(p, axes[-1], 1, dim=dims[-1], periodic=False)
    return p, lo, hi


def _stencil27_matvec_chain(p: jax.Array, axes: Tuple[str, ...],
                            dims: Tuple[int, ...], mode: str,
                            halos=None, subdomains: int = 4) -> jax.Array:
    """y = A p with block decomposition over the mesh dims in `dims` ((y, z)
    or (x, y, z)). `halos` is the :func:`_exchange_chain` triple,
    pre-exchanged by the pipelined CG; the interior chunk tasks along the
    last dim read only the pre-padded block, so just the boundary-plane
    tasks wait on the final ppermute pair."""
    if halos is None:
        halos = _exchange_chain(p, axes, dims)
    p1, lo, hi = halos
    fn = _chain_fn27(dims)
    if mode == "hdot":
        return stencil_with_halo_nd(p1, [(lo, hi)], fn, width=1,
                                    dims=(dims[-1],),
                                    subdomains=(subdomains,))
    return fn(jnp.concatenate([lo, p1, hi], axis=dims[-1]))


def _ddot(a: jax.Array, b: jax.Array, axis_name: Optional[str],
          subdomains: int = 4) -> jax.Array:
    """paper Code 11: per-subdomain reduction(+) partials, then allreduce."""
    prod = (a * b).reshape(-1)
    chunks = jnp.array_split(prod, subdomains)
    partials = [jnp.sum(c, dtype=jnp.float64 if a.dtype == jnp.float64 else jnp.float32)
                for c in chunks]
    local = task_reduce(partials, "sum")
    if axis_name is None:
        return local
    return lax.psum(local, axis_name)


@functools.lru_cache(maxsize=128)
def _hpccg_solver(mesh, mesh_axes, iters: int, mode: str, subdomains: int):
    axes = normalize_mesh_axes(mesh_axes, "hpccg_solve", (1, 2, 3))
    chained = len(axes) >= 2
    # the reduction axes / 1-D exchange axis, in the historical spelling
    # (bare name for slabs, tuple for chained meshes)
    axis_name = axes if chained else axes[0]
    if chained:
        # trailing grid dims carry the mesh: (y, z) for a pair, (x, y, z)
        # for a full 3-D mesh
        cdims = tuple(range(3 - len(axes), 3))

    def matvec(p, halos):
        if chained:
            return _stencil27_matvec_chain(p, axes, cdims, mode, halos=halos,
                                           subdomains=subdomains)
        return _stencil27_matvec(p, axis_name, mode, halos=halos,
                                 subdomains=subdomains)

    def next_halos(p):
        if chained:
            return _exchange_chain(p, axes, cdims)
        return exchange_halo(p, axis_name, width=1, dim=2, periodic=False)

    def local(b_loc):
        x = jnp.zeros_like(b_loc)
        r = b_loc
        p = r
        rtrans = _ddot(r, r, axis_name, subdomains)
        pipelined = mode == "hdot" and b_loc.shape[2] >= 4 and iters > 0

        def step(x, r, p, rtrans, halos):
            Ap = matvec(p, halos)
            alpha = rtrans / _ddot(p, Ap, axis_name, subdomains)
            x = x + alpha * p          # waxpby tasks
            r = r - alpha * Ap
            rtrans_new = _ddot(r, r, axis_name, subdomains)
            beta = rtrans_new / rtrans
            p = r + beta * p
            return x, r, p, rtrans_new

        if pipelined:
            def body(carry, _):
                x, r, p, rtrans, halos = carry
                x, r, p, rtrans = step(x, r, p, rtrans, halos)
                halos = next_halos(p)  # for the NEXT matvec
                return (x, r, p, rtrans, halos), jnp.sqrt(rtrans)

            # drain peeled: the last iteration consumes its halos but feeds
            # no further matvec — same dead-exchange saving as halo_scan
            (x, r, p, rtrans, halos), hist = lax.scan(
                (body), (x, r, p, rtrans, next_halos(p)), None,
                length=iters - 1)
            x, r, p, rtrans = step(x, r, p, rtrans, halos)
            hist = jnp.concatenate([hist, jnp.sqrt(rtrans)[None]])
            return x, hist

        def body(carry, _):
            x, r, p, rtrans = carry
            x, r, p, rtrans = step(x, r, p, rtrans, None)
            return (x, r, p, rtrans), jnp.sqrt(rtrans)

        (x, r, p, rtrans), hist = lax.scan(body, (x, r, p, rtrans), None, length=iters)
        return x, hist

    if chained:
        spec = P(*((None,) * (3 - len(axes)) + axes))
    else:
        spec = P(None, None, axis_name)
    f = jax.shard_map(local, mesh=mesh, in_specs=spec, out_specs=(spec, P()))
    return jax.jit(f)


def hpccg_solve(b: jax.Array, mesh, mesh_axes, iters: int,
                mode: str = "hdot", subdomains: int = 4) -> Tuple[jax.Array, jax.Array]:
    """Unpreconditioned CG on the 27-point system (HPCCG's CG core; the paper
    taskifies ddot/waxpby/sparsemv — here each is an over-decomposed op).
    Returns (x, residual-norm history).

    `mesh_axes` is the unified solver topology contract: ``(z_axis,)``
    (z-stacked slabs), a ``(y_axis, z_axis)`` pair, or an
    ``(x_axis, y_axis, z_axis)`` triple — HPCCG's native full 3-D mesh.
    Multi-axis topologies use the sequential face-message chain
    (:func:`_exchange_chain`): each earlier dim is padded in order on the
    already-padded block, so the last dim's halo planes carry every corner
    coupling of the 27-point operator with one face ppermute pair per axis.

    hdot mode pipelines the matvec halo: the exchange(s) for iteration k+1
    are launched the moment p_{k+1} is formed, so they ride behind the two
    ddot allreduces, the waxpby tasks, and the next matvec's interior chunks
    — only the boundary-plane tasks of the next matvec wait on them. The
    jitted solver is cached per (mesh, topology, iters, mode, subdomains) so
    repeated solves (and benchmark timings) pay compile once."""
    axes = normalize_mesh_axes(mesh_axes, "hpccg_solve", (1, 2, 3))
    return _hpccg_solver(mesh, axes, iters, mode, subdomains)(b)
