"""Double-buffered all-to-all: the `halo_scan` schedule applied to a2a.

An expert-parallel MoE layer moves every routed token twice through a single
monolithic ``all_to_all`` pair (dispatch there, combine back) — the one
collective that dominates large-MoE step time, and in the monolithic form the
exact "bulk communication with zero overlap window" shape the HDOT paper
taskifies away. `a2a_scan` applies the same move as `core.halo.halo_scan`:
over-decompose the transfer along one dim into ``chunks`` slices and schedule

    dispatch a2a(k+1)  ||  compute(k)  ||  combine a2a(k-1)

so every slice's wire time sits inside a neighbor slice's compute. The
prologue (first dispatch) and drain (last combine) are peeled exactly like
halo_scan's first/last exchange.

Trace order per step k (prologue ``dispatch(0)`` already issued):

    dispatch(k+1)        # next slice leaves BEFORE this slice's compute
    y_k = compute(recv_k)
    combine(y_k)         # this slice streams back while k+1 computes

``chunks=1`` emits exactly the monolithic two-a2a program — zero slice or
concat ops — so every existing caller/test is an equivalence oracle for the
chunked path. Chunking is value-preserving whenever ``compute_fn`` treats the
sliced dim elementwise (slicing commutes with both a2as and with the
per-slice compute), which the expert FFN does: its einsums contract only the
feature dim, never the capacity dim being sliced.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat  # noqa: F401  (jax version shims)


def a2a_scan(x: jax.Array,
             compute_fn: Callable[[jax.Array, int], jax.Array],
             axis_name: str, *, chunks: int = 1, dim: int,
             split_axis: int = 0, concat_axis: int = 0) -> jax.Array:
    """dispatch-a2a -> compute -> combine-a2a, double-buffered over ``dim``.

    x          : per-shard array inside a shard_map body.
    compute_fn : (received_slice, k) -> result slice, same rank, same extent
                 along ``dim``. Must be elementwise along ``dim`` for chunking
                 to preserve values.
    axis_name  : mesh axis of both all_to_alls.
    chunks     : number of capacity slices Q. 1 = monolithic (today's
                 schedule); must divide ``x.shape[dim]``.
    dim        : dim to over-decompose (NOT the a2a split/concat dim).
    split_axis / concat_axis : forwarded to both ``lax.all_to_all`` calls.
    """
    if chunks == 1:
        recv = lax.all_to_all(x, axis_name, split_axis, concat_axis)
        return lax.all_to_all(compute_fn(recv, 0), axis_name,
                              split_axis, concat_axis)
    n = x.shape[dim]
    if chunks < 1 or n % chunks != 0:
        raise ValueError(
            f"a2a_scan: chunks={chunks} must be >=1 and divide "
            f"x.shape[{dim}]={n} (x.shape={x.shape})")
    q = n // chunks

    def dispatch(k: int) -> jax.Array:
        sl = lax.slice_in_dim(x, k * q, (k + 1) * q, axis=dim)
        return lax.all_to_all(sl, axis_name, split_axis, concat_axis)

    recv = dispatch(0)                      # prologue: slice 0 on the wire
    outs = []
    for k in range(chunks):
        # issue slice k+1's dispatch BEFORE touching slice k's tokens — the
        # dataflow leaves XLA free to run it under compute_fn(k)
        nxt = dispatch(k + 1) if k + 1 < chunks else None
        y = compute_fn(recv, k)
        # combine streams back while slice k+1 computes; the last combine is
        # the drain (nothing left to hide it behind)
        outs.append(lax.all_to_all(y, axis_name, split_axis, concat_axis))
        recv = nxt
    return jnp.concatenate(outs, axis=dim)
