"""Collective matmuls: the HDOT subdomain idea applied to tensor parallelism.

A Megatron/SP layer computes  y = all_gather(x) @ W  and  z = reduce_scatter(h @ V).
The "two-phase" schedule performs the whole collective, then the whole matmul —
exactly the paper's serial comm/compute phases. The HDOT schedule
over-decomposes the matmul into per-shard chunks (the same partitioning the
mesh already applies!) and rides a ppermute ring: at step k the chunk matmul
runs while the next chunk is in flight. This is the TPU-native analogue of the
paper's "communication tasks" (TAMPI) and was shown for TPUs in
"Overlap communication with dependent computation via decomposition"
[Wang et al., ASPLOS'23]; we implement it with explicit lax.ppermute inside
shard_map so the overlap is structural, not a compiler heuristic.

Conventions (all inside shard_map, mesh axis `axis_name`, P = axis size):
  ag_matmul:  x_local (S/P, M), w_local (M, N/P)  ->  y_local (S, N/P)
  matmul_rs:  h_local (S, N/P), v_local (N/P, M)  ->  z_local (S/P, M)  (= psum_scatter over seq)
Numerics are bit-identical to the two-phase reference modulo fp reassociation
of the reduce order (asserted to ~1e-6 rel in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _ring_perms(n: int):
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


# ------------------------------------------------------------------ two-phase
def ag_matmul_two_phase(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    xg = lax.all_gather(x, axis_name, axis=0, tiled=True)   # (S, M)
    return xg @ w


def matmul_rs_two_phase(h: jax.Array, v: jax.Array, axis_name: str) -> jax.Array:
    z = h @ v                                                # (S, M) partial
    return lax.psum_scatter(z, axis_name, scatter_dimension=0, tiled=True)


# ----------------------------------------------------------------------- HDOT
def ag_matmul_hdot(x: jax.Array, w: jax.Array, axis_name: str,
                   bidirectional: bool = True) -> jax.Array:
    """All-gather matmul as a ppermute ring of chunk "tasks".

    Step k computes the row-block owned by rank (idx - k) [resp (idx + k) on
    the reverse ring] while the next chunk travels. The python loop is
    unrolled: every chunk matmul is independent of the other chunks' permutes,
    so the async scheduler overlaps them (HDOT dataflow, not fork-join)."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x @ w
    idx = lax.axis_index(axis_name)
    s_loc = x.shape[0]
    out = jnp.zeros((n * s_loc, w.shape[1]), dtype=jnp.promote_types(x.dtype, w.dtype))
    fwd, bwd = _ring_perms(n)

    if not bidirectional:
        cur = x
        for k in range(n):
            src = (idx - k) % n                      # owner of the chunk we hold
            out = lax.dynamic_update_slice_in_dim(out, (cur @ w).astype(out.dtype),
                                                  src * s_loc, axis=0)
            if k != n - 1:
                cur = lax.ppermute(cur, axis_name, fwd)
        return out

    # Bidirectional ring: split the local chunk in two, circulate halves in
    # opposite directions — halves the ring latency (beyond-paper optimization;
    # same trick as bidirectional collective matmul on TPU ICI).
    half = s_loc // 2
    if half == 0:
        return ag_matmul_hdot(x, w, axis_name, bidirectional=False)
    lo, hi = x[:half], x[half:]
    steps_fwd = (n + 1) // 2 if n % 2 else n // 2
    cur_lo, cur_hi = lo, hi
    for k in range(n):
        src_lo = (idx - k) % n
        src_hi = (idx + k) % n
        out = lax.dynamic_update_slice_in_dim(out, (cur_lo @ w).astype(out.dtype),
                                              src_lo * s_loc, axis=0)
        out = lax.dynamic_update_slice_in_dim(out, (cur_hi @ w).astype(out.dtype),
                                              src_hi * s_loc + half, axis=0)
        if k != n - 1:
            cur_lo = lax.ppermute(cur_lo, axis_name, fwd)
            cur_hi = lax.ppermute(cur_hi, axis_name, bwd)
    del steps_fwd
    return out


def matmul_rs_hdot(h: jax.Array, v: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter matmul ring: at step k, rank i adds its contribution for
    row-block (i - k - 1) mod n to the travelling accumulator. The chunk
    matmul at step k overlaps the permute of the accumulator from step k-1."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return h @ v
    idx = lax.axis_index(axis_name)
    s = h.shape[0]
    assert s % n == 0, (s, n)
    s_loc = s // n
    fwd, _ = _ring_perms(n)

    acc = None
    for k in range(n):
        b = (idx - k - 1) % n
        h_b = lax.dynamic_slice_in_dim(h, b * s_loc, s_loc, axis=0)
        part = h_b @ v
        acc = part if acc is None else lax.ppermute(acc, axis_name, fwd) + part
    # after n steps rank i holds the full sum for block (i - n) mod n == i...
    # one more hop aligns block (i-? ) — verify: at k=n-1, b=(i-n)%n = i. OK.
    return acc


# ---------------------------------------------------------------- dispatchers
def ag_matmul(x: jax.Array, w: jax.Array, axis_name: str, mode: str = "hdot") -> jax.Array:
    if mode == "hdot":
        return ag_matmul_hdot(x, w, axis_name)
    return ag_matmul_two_phase(x, w, axis_name)


def matmul_rs(h: jax.Array, v: jax.Array, axis_name: str, mode: str = "hdot") -> jax.Array:
    if mode == "hdot":
        return matmul_rs_hdot(h, v, axis_name)
    return matmul_rs_two_phase(h, v, axis_name)
