"""Collective matmuls: the HDOT subdomain idea applied to tensor parallelism.

A Megatron/SP layer computes  y = all_gather(x) @ W  and  z = reduce_scatter(h @ V).
The "two-phase" schedule performs the whole collective, then the whole matmul —
exactly the paper's serial comm/compute phases. The HDOT schedule
over-decomposes the matmul into per-shard chunks (the same partitioning the
mesh already applies!) and rides a ppermute ring: at step k the chunk matmul
runs while the next chunk is in flight. This is the TPU-native analogue of the
paper's "communication tasks" (TAMPI) and was shown for TPUs in
"Overlap communication with dependent computation via decomposition"
[Wang et al., ASPLOS'23]; we implement it with explicit lax.ppermute inside
shard_map so the overlap is structural, not a compiler heuristic.

Conventions (all inside shard_map, mesh axis `axis_name`, P = axis size):
  ag_matmul:  x_local (S/P, M), w_local (M, N/P)  ->  y_local (S, N/P)
  matmul_rs:  h_local (S, N/P), v_local (N/P, M)  ->  z_local (S/P, M)  (= psum_scatter over seq)
Numerics are bit-identical to the two-phase reference modulo fp reassociation
of the reduce order (asserted to ~1e-6 rel in tests).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def _ring_perms(n: int):
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    return fwd, bwd


def _ring_pieces(s_loc: int, bidirectional: bool, chunks) -> list:
    """Chunk-granularity knob: the independent ring 'tasks' the local rows
    are split into, as [(start, stop, backward), ...]. Defaults to 2 pieces
    (one per direction) for bidirectional rings; even pieces ride the forward
    ring, odd pieces the backward ring. Pieces may be uneven (odd/prime s_loc
    still rides both directions); every piece keeps its own static shape."""
    c = chunks if chunks is not None else (2 if bidirectional else 1)
    c = max(1, min(c, s_loc)) if s_loc else 1
    bounds = [(s_loc * i) // c for i in range(c + 1)]
    return [(a, b, (i % 2 == 1) and bidirectional)
            for i, (a, b) in enumerate(zip(bounds[:-1], bounds[1:]))]


# ------------------------------------------------------------------ two-phase
def ag_matmul_two_phase(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    xg = lax.all_gather(x, axis_name, axis=0, tiled=True)   # (S, M)
    return xg @ w


def matmul_rs_two_phase(h: jax.Array, v: jax.Array, axis_name: str) -> jax.Array:
    z = h @ v                                                # (S, M) partial
    return lax.psum_scatter(z, axis_name, scatter_dimension=0, tiled=True)


# ----------------------------------------------------------------------- HDOT
def ag_matmul_hdot(x: jax.Array, w: jax.Array, axis_name: str,
                   bidirectional: bool = True,
                   chunks: Optional[int] = None) -> jax.Array:
    """All-gather matmul as a ppermute ring of chunk "tasks".

    The local rows are split into `chunks` pieces (default 2 when
    bidirectional), each circulating its own ring — even pieces forward, odd
    pieces backward. Step k computes the row-block owned by rank (idx - k)
    [resp (idx + k) on the reverse ring] while the next piece travels. The
    python loop is unrolled: every piece matmul is independent of the other
    pieces' permutes, so the async scheduler overlaps them (HDOT dataflow,
    not fork-join); opposite directions use both halves of a duplex link."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return x @ w
    idx = lax.axis_index(axis_name)
    s_loc = x.shape[0]
    out = jnp.zeros((n * s_loc, w.shape[1]), dtype=jnp.promote_types(x.dtype, w.dtype))
    fwd, bwd = _ring_perms(n)

    pieces = _ring_pieces(s_loc, bidirectional, chunks)
    cur = [x[a:b] for a, b, _ in pieces]
    for k in range(n):
        for c_i, (a, _, backward) in enumerate(pieces):
            src = (idx + k) % n if backward else (idx - k) % n
            out = lax.dynamic_update_slice_in_dim(
                out, (cur[c_i] @ w).astype(out.dtype),
                src * s_loc + a, axis=0)
        if k != n - 1:
            cur = [lax.ppermute(p, axis_name, bwd if backward else fwd)
                   for p, (_, _, backward) in zip(cur, pieces)]
    return out


def matmul_rs_hdot(h: jax.Array, v: jax.Array, axis_name: str,
                   bidirectional: bool = True,
                   chunks: Optional[int] = None) -> jax.Array:
    """Reduce-scatter matmul as `chunks` concurrent accumulator rings.

    The output rows are split into `chunks` pieces (default 2 when
    bidirectional); piece c's accumulator rides its own ring — even pieces
    forward, odd pieces backward — and at step k rank i folds in its
    contribution for row-block (i -/+ k+1) mod n. Replaces the old single
    full-width length-n serial accumulator chain: the chains are independent
    (the scheduler interleaves them and each step's piece matmul overlaps the
    other pieces' permutes), each hop carries 1/chunks of the bytes, and
    opposite directions ride both halves of a duplex link."""
    n = lax.axis_size(axis_name)
    if n == 1:
        return h @ v
    idx = lax.axis_index(axis_name)
    s = h.shape[0]
    if s % n != 0:
        raise ValueError(
            f"gathered dim {s} must divide evenly over the {n} devices of "
            f"axis {axis_name!r} for the ring schedule (got remainder "
            f"{s % n})")
    s_loc = s // n
    fwd, bwd = _ring_perms(n)

    pieces = _ring_pieces(s_loc, bidirectional, chunks)
    accs: list = [None] * len(pieces)
    for k in range(n):
        for c_i, (a0, a1, backward) in enumerate(pieces):
            b = (idx + k + 1) % n if backward else (idx - k - 1) % n
            h_b = lax.dynamic_slice_in_dim(h, b * s_loc + a0, a1 - a0, axis=0)
            part = h_b @ v
            accs[c_i] = part if accs[c_i] is None else lax.ppermute(
                accs[c_i], axis_name, bwd if backward else fwd) + part
    # at k=n-1 the fwd chain lands on b=(i-n)%n == i and the bwd chain on
    # b=(i+n)%n == i: every accumulator holds the full sum for rank i's piece.
    return jnp.concatenate(accs, axis=0)


def ring_permute_count(s_loc: int, n: int, bidirectional: bool = True,
                       chunks: Optional[int] = None) -> int:
    """ppermutes one hdot ring issues: pieces x (n - 1), both directions.
    The PAIR-COUNT lint expectations (analysis/lint_targets) call this so
    they derive from the same `_ring_pieces` split the runtime unrolls —
    changing the chunk policy moves the lint bar with it."""
    if n == 1:
        return 0
    return len(_ring_pieces(s_loc, bidirectional, chunks)) * (n - 1)


# ---------------------------------------------------------------- dispatchers
def ag_matmul(x: jax.Array, w: jax.Array, axis_name: str, mode: str = "hdot",
              chunks: Optional[int] = None) -> jax.Array:
    if mode == "hdot":
        return ag_matmul_hdot(x, w, axis_name, chunks=chunks)
    return ag_matmul_two_phase(x, w, axis_name)


def matmul_rs(h: jax.Array, v: jax.Array, axis_name: str, mode: str = "hdot",
              chunks: Optional[int] = None) -> jax.Array:
    if mode == "hdot":
        return matmul_rs_hdot(h, v, axis_name, chunks=chunks)
    return matmul_rs_two_phase(h, v, axis_name)
