"""Two-phase vs HDOT communication schedules for training (paper §3.1-3.2).

Gradient synchronization is the LM-training analogue of the paper's halo
exchange: the "two-phase" hybrid code computes the whole backward pass, then
performs one monolithic gradient reduction (serial comm phase, Amdahl-capped
— paper Figure 1). The HDOT schedule over-decomposes the gradient set into
layer-aligned buckets (subdomains of the parameter domain!) whose reductions
are independent collectives the XLA scheduler overlaps with remaining
backward compute.

The HDOT sync is ZERO-COPY: each bucket is reduced as a pytree — one
``lax.psum`` over the bucket's leaf tuple, which XLA lowers to a single
multi-operand all-reduce operating on the gradient buffers in place. No
flatten/concatenate staging copy, no post-reduce reslice, and no dtype
round-trip (each leaf is reduced in its own dtype), unlike the two-phase
baseline which pays two full-parameter-size copies plus an upcast per step.

With layer provenance on the gradient leaves (``models/layers.py`` tags every
ParamSpec with its forward depth), buckets are cut along layer boundaries and
their collectives are EMITTED reverse-topologically — last-backward-first: the
head/final-layer bucket's reduction enters the program first, so the XLA
latency-hiding scheduler (which prioritizes collectives by program order) can
launch it while earlier layers' backward is still computing, instead of
serializing every reduction behind the full backward the way tree-order
emission does.

The FSDP (ZeRO-3) composition applies the same bucket decomposition to the
PARAMETER domain: each bucket lives as a flat buffer sharded over the DP axes
(1/|dp| per-device residency), all-gathered bucket-wise in forward order at
the top of the step and reduce-scattered bucket-wise in reverse-topological
order in the backward — the HDOT subdomain schedule on both halves of the
parameter life-cycle.

Also provides microbatch gradient accumulation (the sequence-of-subdomains
view of the global batch) used by the trainer and by the dry-run.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any
AxisNames = Union[str, Sequence[str]]


# ------------------------------------------------------------------ bucketing
def make_buckets(tree: PyTree, num_buckets: int,
                 layers: Optional[PyTree] = None,
                 order: str = "reverse_topo") -> List[List[Tuple[int, Any]]]:
    """Group tree leaves into at most `num_buckets` buckets — the HDOT
    subdomains of the gradient domain. Returns [[(leaf_idx, leaf), ...], ...]
    in collective EMISSION order.

    Without `layers`: greedy size-balanced grouping, leaf order preserved
    inside a bucket (the legacy schedule; emission order is tree order).

    With `layers` (a pytree of int forward depths matching `tree`, e.g.
    ``LanguageModel.param_layers()``): leaves are grouped by depth, depth
    groups are merged into ~size-balanced CONTIGUOUS buckets (cuts only at
    layer boundaries), and the bucket list is ordered by `order`:

      'reverse_topo'  deepest (last-backward) first — the bucket whose grads
                      complete earliest in the backward pass is emitted first,
                      so its collective overlaps the remaining backward.
      'tree'          shallowest first (forward/tree order).
      'layer'         one bucket PER distinct depth, shallowest first — the
                      per-layer cut streaming ZeRO-3 needs so each bucket's
                      all-gather can be emitted just before the single layer
                      that consumes it (`num_buckets` is ignored).
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return []
    num_buckets = max(1, min(num_buckets, len(leaves)))
    if layers is None:
        sizes = [(i, _leaf_size(l)) for i, l in enumerate(leaves)]
        # greedy: biggest leaf into currently-smallest bucket
        buckets: List[List[int]] = [[] for _ in range(num_buckets)]
        load = [0] * num_buckets
        for i, sz in sorted(sizes, key=lambda t: -t[1]):
            b = load.index(min(load))
            buckets[b].append(i)
            load[b] += sz
        return [[(i, leaves[i]) for i in sorted(b)] for b in buckets if b]

    if order not in ("reverse_topo", "tree", "layer"):
        raise ValueError(f"unknown bucket order {order!r}")
    tags = jax.tree.leaves(layers)
    if len(tags) != len(leaves):
        raise ValueError(
            f"layer-provenance tree has {len(tags)} leaves but the gradient "
            f"tree has {len(leaves)} — tag every leaf (models/*.py)")
    by_depth: Dict[int, List[int]] = {}
    for i, t in enumerate(tags):
        by_depth.setdefault(int(t), []).append(i)
    if order == "layer":
        return [[(i, leaves[i]) for i in sorted(by_depth[d])]
                for d in sorted(by_depth)]
    depths = sorted(by_depth, reverse=(order == "reverse_topo"))
    total = sum(_leaf_size(leaves[i]) for i in range(len(leaves)))
    # contiguous partition of the depth sequence: group g goes to the bucket
    # its cumulative-size midpoint falls in — cuts land only on layer
    # boundaries, loads stay within one layer's size of balanced
    buckets, cum = [[] for _ in range(num_buckets)], 0
    for d in depths:
        size_d = sum(_leaf_size(leaves[i]) for i in by_depth[d])
        b = min(num_buckets - 1, (cum + size_d // 2) * num_buckets // total)
        buckets[b].extend(sorted(by_depth[d]))
        cum += size_d
    return [[(i, leaves[i]) for i in b] for b in buckets if b]


def _leaf_size(leaf: Any) -> int:
    size = getattr(leaf, "size", None)
    if size is None:
        shape = getattr(leaf, "shape", ())
        size = math.prod(shape) if shape else 1
    return int(size)


def grad_sync_two_phase(grads: PyTree, axes: AxisNames) -> PyTree:
    """Paper baseline: ONE monolithic reduction of the flattened gradient.
    Maximally serialized — nothing can overlap a single fused collective."""
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads  # nothing to reduce: don't emit a zero-size collective
    flat = jnp.concatenate([l.reshape(-1) for l in leaves])
    flat = lax.psum(flat, axes)
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def grad_sync_hdot(grads: PyTree, axes: AxisNames, num_buckets: int = 8,
                   layers: Optional[PyTree] = None,
                   order: str = "reverse_topo") -> PyTree:
    """HDOT: per-bucket reductions — independent collectives that the
    latency-hiding scheduler interleaves with compute (and with each other).

    Zero-copy: a bucket is reduced as ONE ``lax.psum`` over its leaf tuple
    (a single multi-operand all-reduce), so leaves are never concatenated
    into a staging buffer, never resliced, and keep their dtypes.

    With `layers` (leaf-wise forward depths) the buckets are cut along layer
    boundaries and their psums emitted last-backward-first (see
    :func:`make_buckets`), so the first reduction departs while earlier
    layers' backward is still computing."""
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads
    synced: dict = {}
    for bucket in make_buckets(grads, num_buckets, layers=layers, order=order):
        idxs = tuple(i for i, _ in bucket)
        reduced = lax.psum(tuple(v for _, v in bucket), axes)
        synced.update(zip(idxs, reduced))
    return jax.tree.unflatten(treedef, [synced[i] for i in range(len(leaves))])


def grad_sync(grads: PyTree, axes: AxisNames, mode: str = "hdot",
              num_buckets: int = 8, layers: Optional[PyTree] = None,
              order: str = "reverse_topo") -> PyTree:
    if mode == "hdot":
        return grad_sync_hdot(grads, axes, num_buckets, layers=layers,
                              order=order)
    if mode in ("none", "two_phase"):
        return grad_sync_two_phase(grads, axes)
    raise ValueError(f"unknown overlap mode {mode!r}")


# --------------------------------------------------------- microbatch accum
def microbatch_split(batch: PyTree, steps: int) -> PyTree:
    """(B, ...) -> (steps, B/steps, ...) for scan-based accumulation."""
    def split(x):
        b = x.shape[0]
        if b % steps != 0:
            # a bare assert vanishes under `python -O` and the reshape below
            # then fails with a shapeless size-mismatch error
            raise ValueError(
                f"global batch {b} is not divisible by accum steps {steps}")
        return x.reshape(steps, b // steps, *x.shape[1:])
    return jax.tree.map(split, batch)


def accumulate_grads(loss_and_grad: Callable[[PyTree, PyTree], Tuple[jax.Array, PyTree]],
                     params: PyTree, batch: PyTree, steps: int) -> Tuple[jax.Array, PyTree]:
    """Gradient accumulation over `steps` microbatches via lax.scan.

    Each microbatch is a task-level subdomain of the global batch (the HDOT
    over-decomposition along the batch axis); partial gradients are the
    task-level reduction partials, accumulated in fp32."""
    if steps == 1:
        return loss_and_grad(params, batch)

    micro = microbatch_split(batch, steps)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = loss_and_grad(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (loss_acc + loss.astype(jnp.float32), g_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, g_sum), _ = lax.scan(body, (jnp.zeros((), jnp.float32), g0), micro)
    inv = 1.0 / steps
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)


# ----------------------------------------------------- FSDP (ZeRO-3) buckets
@dataclass(frozen=True)
class FsdpGroup:
    """One flat parameter buffer: a grad-sync bucket restricted to one dtype
    (buffers are concatenations, so leaves of different dtypes in the same
    bucket get sibling buffers sharing the bucket's schedule slot)."""

    key: str                          # buffer name in the flat state dict
    bucket: int                       # forward-order bucket index
    dtype: Any
    leaf_idx: Tuple[int, ...]         # leaves packed into this buffer
    shapes: Tuple[Tuple[int, ...], ...]
    offsets: Tuple[int, ...]          # leaf start offsets in the buffer
    size: int                         # unpadded element count
    padded: int                       # size rounded up to n_shards


@dataclass(frozen=True)
class FsdpLayout:
    """Bucket-wise flat-buffer layout of a parameter tree for ZeRO-3 sharding
    over the DP axes. ``groups`` is stored in FORWARD order (bucket 0 =
    shallowest = embedding end); the backward reduce-scatter iterates it in
    reverse — last-backward bucket first."""

    groups: Tuple[FsdpGroup, ...]
    treedef: Any
    n_shards: int
    num_leaves: int

    @property
    def keys(self) -> Tuple[str, ...]:
        return tuple(g.key for g in self.groups)

    def shard_bytes(self) -> int:
        """Per-device bytes of one parameter copy under this layout."""
        return sum(g.padded // self.n_shards * jnp.dtype(g.dtype).itemsize
                   for g in self.groups)


def fsdp_layout(tree: PyTree, n_shards: int, num_buckets: int = 8,
                layers: Optional[PyTree] = None,
                order: str = "reverse_topo") -> FsdpLayout:
    """Cut `tree` (params or matching abstract specs) into the per-bucket flat
    buffers of the ZeRO-3 schedule. Buckets follow :func:`make_buckets`
    (layer-boundary cuts when `layers` is given); each is split by dtype into
    concatenable buffers padded up to a multiple of `n_shards`."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("fsdp_layout needs a non-empty parameter tree")
    buckets = make_buckets(tree, num_buckets, layers=layers, order=order)
    if layers is not None and order == "reverse_topo":
        buckets = buckets[::-1]  # store forward order; RS iterates reversed
    groups: List[FsdpGroup] = []
    for b, bucket in enumerate(buckets):
        by_dtype: Dict[Any, List[int]] = {}
        for i, leaf in bucket:
            by_dtype.setdefault(jnp.dtype(leaf.dtype).name, []).append(i)
        for dtype_name, idxs in sorted(by_dtype.items()):
            shapes = tuple(tuple(leaves[i].shape) for i in idxs)
            sizes = [_leaf_size(leaves[i]) for i in idxs]
            offsets, off = [], 0
            for s in sizes:
                offsets.append(off)
                off += s
            padded = -(-off // n_shards) * n_shards
            groups.append(FsdpGroup(
                key=f"b{b:02d}_{dtype_name}", bucket=b, dtype=dtype_name,
                leaf_idx=tuple(idxs), shapes=shapes, offsets=tuple(offsets),
                size=off, padded=padded))
    return FsdpLayout(groups=tuple(groups), treedef=treedef,
                      n_shards=n_shards, num_leaves=len(leaves))


def _pack_group(leaves: List[Any], g: FsdpGroup) -> jax.Array:
    """Concatenate a group's leaves into its flat (padded) buffer."""
    flat = [leaves[i].reshape(-1) for i in g.leaf_idx]
    buf = flat[0] if len(flat) == 1 else jnp.concatenate(flat)
    if g.padded > g.size:
        buf = jnp.pad(buf, (0, g.padded - g.size))
    return buf


def _unpack_group(buf: jax.Array, g: FsdpGroup, out: List[Any]) -> None:
    """Slice a group's full flat buffer back into its leaves (into `out`)."""
    for i, off, shape in zip(g.leaf_idx, g.offsets, g.shapes):
        size = math.prod(shape) if shape else 1
        out[i] = buf[off:off + size].reshape(shape)


def fsdp_shard_full(tree: PyTree, layout: FsdpLayout) -> Dict[str, jax.Array]:
    """GLOBAL view: params tree -> {key: flat (padded,) buffer}. Place each
    buffer with ``NamedSharding(mesh, P(dp_axes))`` and per-device parameter
    residency drops to 1/n_shards."""
    leaves = jax.tree.leaves(tree)
    if len(leaves) != layout.num_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, layout expects "
                         f"{layout.num_leaves}")
    return {g.key: _pack_group(leaves, g) for g in layout.groups}


def fsdp_unshard_full(flat: Dict[str, jax.Array], layout: FsdpLayout) -> PyTree:
    """GLOBAL view: {key: flat buffer} -> params tree (inverse of
    :func:`fsdp_shard_full`; also reshapes optimizer-moment buffers, whose
    dtype may differ from the params')."""
    out: List[Any] = [None] * layout.num_leaves
    for g in layout.groups:
        _unpack_group(flat[g.key], g, out)
    return jax.tree.unflatten(layout.treedef, out)


def fsdp_all_gather(local: Dict[str, jax.Array], layout: FsdpLayout,
                    axes: AxisNames) -> PyTree:
    """Inside shard_map: bucket-wise all-gather of the parameter shards, FULL
    params tree out. Buffers are gathered in FORWARD order (bucket 0 first):
    the embedding-end bucket the forward pass needs first is also the first
    collective in the program, so later buckets' gathers overlap the early
    layers' compute."""
    out: List[Any] = [None] * layout.num_leaves
    for g in layout.groups:
        full = lax.all_gather(local[g.key], axes, axis=0, tiled=True)
        _unpack_group(full, g, out)
    return jax.tree.unflatten(layout.treedef, out)


def fsdp_relayout(flat: Dict[str, jax.Array], old: FsdpLayout,
                  new: FsdpLayout) -> Dict[str, jax.Array]:
    """Re-cut flat FSDP buffers from one layout to another — the checkpoint
    portability path: a committed checkpoint written under `old` (some
    `grad_buckets` / `bucket_order` / mesh size) is imported under `new` by
    unsharding with the OLD layout and resharding with the NEW. Works for
    optimizer-moment buffers too: dtypes follow the buffers, not the layout,
    so f32 moments stay f32 through the re-cut. Bit-exact: unpacking drops
    only pad elements and repacking re-pads with zeros."""
    if old.num_leaves != new.num_leaves:
        raise ValueError(
            f"cannot re-layout: old layout has {old.num_leaves} leaves, new "
            f"has {new.num_leaves} — the parameter tree itself changed")
    leaves = jax.tree.leaves(fsdp_unshard_full(flat, old))
    return {g.key: _pack_group(leaves, g) for g in new.groups}


# ------------------------------------------------- streaming ZeRO-3 schedule
@dataclass(frozen=True)
class FsdpStream:
    """Gather/free schedule for streaming ZeRO-3: the layer→bucket map.

    Built from a per-layer layout (``order='layer'``) plus the same
    layer-provenance tree that cut it, this maps each forward depth to the
    flat buffers holding exactly that depth's parameters. The streamed step
    calls :meth:`materialize` INSIDE each layer's remat region, so a bucket's
    all-gather is emitted just before the first (and only) layer that consumes
    it, the gathered buffer dies at the end of the layer's forward, and the
    backward's rematerialization re-emits the gathers in REVERSE layer order —
    peak live params ≈ shard + a 2-bucket working set instead of the full
    tree. AD transposes each tiled ``lax.all_gather`` into a tiled
    ``lax.psum_scatter``, so per-bucket reduce-scatters are emitted
    last-backward-first automatically (no explicit ``grad_sync_fsdp``)."""

    layout: FsdpLayout
    axes: AxisNames
    depth_groups: Tuple[Tuple[int, Tuple[FsdpGroup, ...]], ...]

    @property
    def depths(self) -> Tuple[int, ...]:
        """Forward depths with parameters, shallowest first."""
        return tuple(d for d, _ in self.depth_groups)

    def groups_at(self, *depths: int) -> Tuple[FsdpGroup, ...]:
        by_depth = dict(self.depth_groups)
        return sum((by_depth.get(d, ()) for d in depths), ())

    def flat_at(self, pflat: Dict[str, jax.Array],
                *depths: int) -> Dict[str, jax.Array]:
        """The shard-resident sub-dict feeding `depths`' remat region (its
        residuals: the backward regathers from these, not from the full)."""
        return {g.key: pflat[g.key] for g in self.groups_at(*depths)}

    def materialize(self, flat: Dict[str, jax.Array], *depths: int) -> PyTree:
        """All-gather the buffers of `depths` and unpack them into a params
        tree with ``None`` holes everywhere else. Call inside the consuming
        remat region: trace order puts each gather next to its layer."""
        out: List[Any] = [None] * self.layout.num_leaves
        for g in self.groups_at(*depths):
            full = lax.all_gather(flat[g.key], self.axes, axis=0, tiled=True)
            _unpack_group(full, g, out)
        return jax.tree.unflatten(self.layout.treedef, out)


def fsdp_stream(layout: FsdpLayout, layers: PyTree,
                axes: AxisNames) -> FsdpStream:
    """Build the streaming gather/free schedule from a per-layer layout and
    its layer-provenance tree. Every buffer must cover exactly ONE forward
    depth (build the layout with ``order='layer'``)."""
    tags = jax.tree.leaves(layers)
    if len(tags) != layout.num_leaves:
        raise ValueError(
            f"layer-provenance tree has {len(tags)} leaves but the layout "
            f"packs {layout.num_leaves}")
    depth_groups: Dict[int, List[FsdpGroup]] = {}
    for g in layout.groups:
        ds = sorted({int(tags[i]) for i in g.leaf_idx})
        if len(ds) != 1:
            raise ValueError(
                f"streaming ZeRO-3 needs per-layer buckets: buffer {g.key} "
                f"spans forward depths {ds} — cut the layout with "
                "order='layer'")
        depth_groups.setdefault(ds[0], []).append(g)
    return FsdpStream(
        layout=layout, axes=axes,
        depth_groups=tuple((d, tuple(depth_groups[d]))
                           for d in sorted(depth_groups)))


def grad_sync_fsdp(grads: PyTree, layout: FsdpLayout,
                   axes: AxisNames) -> Dict[str, jax.Array]:
    """Inside shard_map: bucket-wise reduce-scatter of the gradients — the
    ZeRO-3 half of the HDOT schedule. One ``lax.psum_scatter`` per flat
    buffer, EMITTED in reverse-topological order (last bucket of the layout
    first): the head bucket's gradients are complete earliest in the backward
    pass, so its reduction is first in program order and departs while the
    earlier layers' backward is still computing. Returns {key: local shard}
    of the SUM over `axes` (divide by the shard count for the mean)."""
    leaves, treedef = jax.tree.flatten(grads)
    if treedef != layout.treedef:
        raise ValueError("gradient tree does not match the FSDP layout")
    return {g.key: lax.psum_scatter(_pack_group(leaves, g), axes,
                                    scatter_dimension=0, tiled=True)
            for g in reversed(layout.groups)}
