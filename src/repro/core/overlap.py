"""Two-phase vs HDOT communication schedules for training (paper §3.1-3.2).

Gradient synchronization is the LM-training analogue of the paper's halo
exchange: the "two-phase" hybrid code computes the whole backward pass, then
performs one monolithic gradient reduction (serial comm phase, Amdahl-capped
— paper Figure 1). The HDOT schedule over-decomposes the gradient set into
layer-aligned buckets (subdomains of the parameter domain!) whose reductions
are independent collectives the XLA scheduler overlaps with remaining
backward compute.

The HDOT sync is ZERO-COPY: each bucket is reduced as a pytree — one
``lax.psum`` over the bucket's leaf tuple, which XLA lowers to a single
multi-operand all-reduce operating on the gradient buffers in place. No
flatten/concatenate staging copy, no post-reduce reslice, and no dtype
round-trip (each leaf is reduced in its own dtype), unlike the two-phase
baseline which pays two full-parameter-size copies plus an upcast per step.

Also provides microbatch gradient accumulation (the sequence-of-subdomains
view of the global batch) used by the trainer and by the dry-run.
"""
from __future__ import annotations

from typing import Any, Callable, List, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any
AxisNames = Union[str, Sequence[str]]


# ------------------------------------------------------------------ bucketing
def make_buckets(tree: PyTree, num_buckets: int) -> List[List[Tuple[int, Any]]]:
    """Greedy size-balanced grouping of tree leaves into `num_buckets` buckets.
    Leaf ORDER is preserved inside a bucket; buckets are the HDOT subdomains of
    the gradient domain. Returns [[(leaf_idx, leaf), ...], ...]."""
    leaves = jax.tree.leaves(tree)
    sizes = [(i, int(getattr(l, "size", 1))) for i, l in enumerate(leaves)]
    num_buckets = max(1, min(num_buckets, len(leaves)))
    # greedy: biggest leaf into currently-smallest bucket
    buckets: List[List[int]] = [[] for _ in range(num_buckets)]
    load = [0] * num_buckets
    for i, sz in sorted(sizes, key=lambda t: -t[1]):
        b = load.index(min(load))
        buckets[b].append(i)
        load[b] += sz
    return [[(i, leaves[i]) for i in sorted(b)] for b in buckets if b]


def grad_sync_two_phase(grads: PyTree, axes: AxisNames) -> PyTree:
    """Paper baseline: ONE monolithic reduction of the flattened gradient.
    Maximally serialized — nothing can overlap a single fused collective."""
    leaves, treedef = jax.tree.flatten(grads)
    flat = jnp.concatenate([l.reshape(-1) for l in leaves]) if leaves else jnp.zeros((0,))
    flat = lax.psum(flat, axes)
    out, off = [], 0
    for l in leaves:
        out.append(flat[off:off + l.size].reshape(l.shape).astype(l.dtype))
        off += l.size
    return jax.tree.unflatten(treedef, out)


def grad_sync_hdot(grads: PyTree, axes: AxisNames, num_buckets: int = 8) -> PyTree:
    """HDOT: per-bucket reductions — independent collectives that the
    latency-hiding scheduler interleaves with compute (and with each other).

    Zero-copy: a bucket is reduced as ONE ``lax.psum`` over its leaf tuple
    (a single multi-operand all-reduce), so leaves are never concatenated
    into a staging buffer, never resliced, and keep their dtypes."""
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        return grads
    synced: dict = {}
    for bucket in make_buckets(grads, num_buckets):
        idxs = tuple(i for i, _ in bucket)
        reduced = lax.psum(tuple(v for _, v in bucket), axes)
        synced.update(zip(idxs, reduced))
    return jax.tree.unflatten(treedef, [synced[i] for i in range(len(leaves))])


def grad_sync(grads: PyTree, axes: AxisNames, mode: str = "hdot",
              num_buckets: int = 8) -> PyTree:
    if mode == "hdot":
        return grad_sync_hdot(grads, axes, num_buckets)
    if mode in ("none", "two_phase"):
        return grad_sync_two_phase(grads, axes)
    raise ValueError(f"unknown overlap mode {mode!r}")


# --------------------------------------------------------- microbatch accum
def microbatch_split(batch: PyTree, steps: int) -> PyTree:
    """(B, ...) -> (steps, B/steps, ...) for scan-based accumulation."""
    def split(x):
        b = x.shape[0]
        assert b % steps == 0, f"batch {b} not divisible by accum steps {steps}"
        return x.reshape(steps, b // steps, *x.shape[1:])
    return jax.tree.map(split, batch)


def accumulate_grads(loss_and_grad: Callable[[PyTree, PyTree], Tuple[jax.Array, PyTree]],
                     params: PyTree, batch: PyTree, steps: int) -> Tuple[jax.Array, PyTree]:
    """Gradient accumulation over `steps` microbatches via lax.scan.

    Each microbatch is a task-level subdomain of the global batch (the HDOT
    over-decomposition along the batch axis); partial gradients are the
    task-level reduction partials, accumulated in fp32."""
    if steps == 1:
        return loss_and_grad(params, batch)

    micro = microbatch_split(batch, steps)

    def body(carry, mb):
        loss_acc, g_acc = carry
        loss, g = loss_and_grad(params, mb)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
        return (loss_acc + loss.astype(jnp.float32), g_acc), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (loss_sum, g_sum), _ = lax.scan(body, (jnp.zeros((), jnp.float32), g0), micro)
    inv = 1.0 / steps
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)
