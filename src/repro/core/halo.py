"""Halo exchange with interior/boundary overlap (paper §3.2, Figure 3).

Two schedules over the same decomposition:

- ``two_phase``  — the paper's MPI+OpenMP baseline: exchange ALL halos, then
  compute the whole block. The compute depends on every halo, so communication
  serializes with computation (fork-join / "two-phase programming").

- ``hdot``       — the paper's technique: the local block is over-decomposed
  into interior + boundary subdomains. Boundary strips are the only consumers
  of the halo ppermutes, so the (much larger) interior compute is independent
  of communication and XLA's async latency-hiding scheduler overlaps them —
  the SPMD analogue of OmpSs-2 tasks with fine-grained `inout(subdomain)`
  dependencies plus TAMPI-style asynchronous communication.

The hdot schedule over-decomposes the interior into ``subdomains`` chunk
tasks, each reading ONLY its slice of the source (plus `width` ghost rows), so
boundary strips are computed exactly once and the scheduler sees several
independent interior tasks to hide the exchange behind.

For multi-step solvers, :func:`halo_scan` is a double-buffered driver: the
halos for step k+1 ride a ppermute issued as soon as step k's boundary strips
are done — i.e. the exchange for the NEXT step is in flight while the CURRENT
step's interior chunks compute, removing the per-step comm/compute dependency
chain entirely (one pipeline-fill exchange at the start is the only exposed
latency).

All functions run inside ``shard_map`` bodies; `axis_name` names the mesh axis
that carries the process-level domain decomposition for `dim`.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _edge(u: jax.Array, dim: int, side: str, width: int) -> jax.Array:
    n = u.shape[dim]
    if side == "lo":
        return lax.slice_in_dim(u, 0, width, axis=dim)
    return lax.slice_in_dim(u, n - width, n, axis=dim)


def exchange_edges(lo_edge: jax.Array, hi_edge: jax.Array, axis_name: str,
                   periodic: bool = False) -> Tuple[jax.Array, jax.Array]:
    """ppermute pre-sliced edge strips; returns (lo_halo, hi_halo).

    The lo halo is the PREVIOUS rank's hi edge (sent "forward"), the hi halo
    the NEXT rank's lo edge (sent "backward"). Taking the edges as arguments
    (instead of slicing internally) lets pipelined callers hand over freshly
    computed boundary strips, so the ppermute depends only on those strips —
    not on the assembled block — and can launch while interior tasks run.

    Non-periodic edge shards receive zeros (ppermute semantics), matching the
    paper's `isBoundary` gating — the zero halo is masked out by callers that
    use boundary conditions.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        if periodic:  # wrap around to own edges
            return hi_edge, lo_edge
        return jnp.zeros_like(hi_edge), jnp.zeros_like(lo_edge)
    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
    else:
        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i, i - 1) for i in range(1, n)]
    lo_halo = lax.ppermute(hi_edge, axis_name, fwd)
    hi_halo = lax.ppermute(lo_edge, axis_name, bwd)
    return lo_halo, hi_halo


def exchange_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (lo_halo, hi_halo): the neighbor edges this shard receives."""
    return exchange_edges(_edge(u, dim, "lo", width), _edge(u, dim, "hi", width),
                          axis_name, periodic)


def pad_with_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> jax.Array:
    """Two-phase building block: concat [lo_halo, u, hi_halo] along `dim`."""
    lo, hi = exchange_halo(u, axis_name, width, dim, periodic)
    return jnp.concatenate([lo, u, hi], axis=dim)


# --------------------------------------------------------------------------
# Stencil application schedules.
#
# `stencil_fn(padded)` consumes a block padded by `width` ghost cells on BOTH
# ends of `dim` and must return the updated un-padded block (shape of the
# interior of `padded` along `dim`). "Star"-shaped stencils only: corners
# between two decomposed dims are not exchanged (sufficient for the paper's
# Heat2D 5-point and CREAMS per-direction WENO stencils).
# --------------------------------------------------------------------------

def stencil_two_phase(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                      axis_name: str, width: int, dim: int,
                      periodic: bool = False) -> jax.Array:
    """comm(D); barrier; compute(D) — paper Code 2."""
    padded = pad_with_halo(u, axis_name, width, dim, periodic)
    return stencil_fn(padded)


def _interior_chunks(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                     width: int, dim: int, subdomains: int) -> List[jax.Array]:
    """Interior cells [width, n-width) as up to `subdomains` independent chunk
    tasks (the paper's grainsize knob, Code 4's `for s in subdomains`).

    The chunk covering cells [a, b) reads ONLY u[a-width : b+width] — each
    task's footprint is its subdomain plus `width` ghost cells, so boundary
    strips are never recomputed and the chunks are disjoint work the
    latency-hiding scheduler interleaves with the halo ppermutes."""
    n = u.shape[dim]
    m = n - 2 * width                     # interior cell count
    k = max(1, min(subdomains, m // max(1, 2 * width)))  # keep chunks >= 2*width
    if k == 1:
        return [stencil_fn(u)]           # one interior task, full ghost context
    bounds = [width + (m * t) // k for t in range(k + 1)]
    return [stencil_fn(lax.slice_in_dim(u, a - width, b + width, axis=dim))
            for a, b in zip(bounds[:-1], bounds[1:])]


def _boundary_srcs(u: jax.Array, lo_halo: jax.Array, hi_halo: jax.Array,
                   width: int, dim: int) -> Tuple[jax.Array, jax.Array]:
    n = u.shape[dim]
    lo_src = jnp.concatenate(
        [lo_halo, lax.slice_in_dim(u, 0, 2 * width, axis=dim)], axis=dim)
    hi_src = jnp.concatenate(
        [lax.slice_in_dim(u, n - 2 * width, n, axis=dim), hi_halo], axis=dim)
    return lo_src, hi_src


def stencil_with_halo(u: jax.Array, lo_halo: jax.Array, hi_halo: jax.Array,
                      stencil_fn: Callable[[jax.Array], jax.Array],
                      width: int, dim: int, subdomains: int = 4) -> jax.Array:
    """Communication-free half of the hdot schedule: apply `stencil_fn` to a
    block whose halos were ALREADY received (e.g. pipelined by halo_scan or a
    solver carrying halos across iterations). Boundary strips consume the
    halos; the interior is over-decomposed into `subdomains` chunk tasks."""
    n = u.shape[dim]
    if n < 4 * width:  # degenerate block: no interior to split off
        return stencil_fn(jnp.concatenate([lo_halo, u, hi_halo], axis=dim))
    lo_src, hi_src = _boundary_srcs(u, lo_halo, hi_halo, width, dim)
    lo_out = stencil_fn(lo_src)                  # updates cells [0, width)
    hi_out = stencil_fn(hi_src)                  # updates cells [n-width, n)
    interior = _interior_chunks(u, stencil_fn, width, dim, subdomains)
    return jnp.concatenate([lo_out, *interior, hi_out], axis=dim)


def stencil_hdot(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                 axis_name: str, width: int, dim: int,
                 periodic: bool = False,
                 subdomains: int = 4) -> jax.Array:
    """Interior/boundary over-decomposition (paper Code 4).

    The interior — split into `subdomains` chunk tasks, each reading only its
    own slice plus ghosts — depends only on `u`; the two boundary strips are
    the sole consumers of the halo ppermutes. Chunks are concatenated back, so
    numerics are identical to the two-phase schedule (asserted in tests).
    """
    n = u.shape[dim]
    if n < 4 * width:  # degenerate block: no interior to overlap with
        return stencil_two_phase(u, stencil_fn, axis_name, width, dim, periodic)
    lo_halo, hi_halo = exchange_halo(u, axis_name, width, dim, periodic)
    return stencil_with_halo(u, lo_halo, hi_halo, stencil_fn, width, dim,
                             subdomains)


def stencil_apply(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                  axis_name: str, width: int, dim: int,
                  periodic: bool = False, mode: str = "hdot",
                  subdomains: int = 4) -> jax.Array:
    if mode == "hdot":
        return stencil_hdot(u, stencil_fn, axis_name, width, dim, periodic, subdomains)
    if mode in ("none", "two_phase"):
        return stencil_two_phase(u, stencil_fn, axis_name, width, dim, periodic)
    raise ValueError(f"unknown overlap mode {mode!r}")


def halo_scan(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
              axis_name: str, width: int, dim: int, steps: int,
              periodic: bool = False, mode: str = "hdot",
              subdomains: int = 4,
              step_out_fn: Optional[Callable[[jax.Array, jax.Array], jax.Array]]
              = None) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Double-buffered multi-step stencil driver (lax.scan over `steps`).

    In hdot mode the scan carry is (block, lo_halo, hi_halo): the halos for
    step k arrive with the carry, so the body can (1) finish step k's boundary
    strips, (2) IMMEDIATELY launch the ppermute that feeds step k+1 — the new
    block's edges are exactly those boundary outputs — and (3) only then chew
    through step k's interior chunk tasks. The exchange for the next step is
    therefore always in flight behind the current step's interior compute; the
    only exposed latency is the single pipeline-fill exchange before the scan.

    `step_out_fn(u_new, u_old)` optionally produces a per-step output (e.g. a
    residual); its stacked results are returned as the second element (None
    when not provided). Numerics are identical to `steps` iterated calls of
    :func:`stencil_apply` — asserted in tests.
    """
    n = u.shape[dim]
    if mode != "hdot" or n < 4 * width:
        # two-phase baseline (or degenerate block): plain comm->compute scan
        def body(u, _):
            u_new = stencil_apply(u, stencil_fn, axis_name, width, dim,
                                  periodic, mode, subdomains)
            return u_new, step_out_fn(u_new, u) if step_out_fn else None
        return lax.scan(body, u, None, length=steps)

    def body(carry, _):
        u, lo_halo, hi_halo = carry
        lo_src, hi_src = _boundary_srcs(u, lo_halo, hi_halo, width, dim)
        lo_out = stencil_fn(lo_src)              # new cells [0, width)
        hi_out = stencil_fn(hi_src)              # new cells [n-width, n)
        # The updated block's edge strips ARE lo_out/hi_out — hand them to the
        # ring now so the next step's halos travel while the interior computes.
        lo_next, hi_next = exchange_edges(lo_out, hi_out, axis_name, periodic)
        interior = _interior_chunks(u, stencil_fn, width, dim, subdomains)
        u_new = jnp.concatenate([lo_out, *interior, hi_out], axis=dim)
        out = step_out_fn(u_new, u) if step_out_fn else None
        return (u_new, lo_next, hi_next), out

    lo0, hi0 = exchange_halo(u, axis_name, width, dim, periodic)  # pipeline fill
    (u, _, _), outs = lax.scan(body, (u, lo0, hi0), None, length=steps)
    return u, outs


def multi_dim_stencil(u: jax.Array,
                      per_dim_fn: Callable[[jax.Array, int], jax.Array],
                      decomp: Sequence[Tuple[int, Optional[str]]],
                      width: int, periodic: bool = False,
                      mode: str = "hdot") -> jax.Array:
    """Apply a direction-split stencil along several decomposed dims (the
    CREAMS pattern: euler_LLF_x/y/z are separate per-direction stencils whose
    results sum). `decomp` lists (dim, mesh_axis_or_None); un-sharded dims use
    a local pad."""
    total = None
    for dim, axis_name in decomp:
        fn = partial(per_dim_fn, dim=dim)
        if axis_name is None:
            if periodic:
                padded = jnp.concatenate(
                    [_edge(u, dim, "hi", width), u, _edge(u, dim, "lo", width)], axis=dim)
            else:
                pads = [(0, 0)] * u.ndim
                pads[dim] = (width, width)
                padded = jnp.pad(u, pads)
            out = fn(padded)
        else:
            out = stencil_apply(u, fn, axis_name, width, dim, periodic, mode)
        total = out if total is None else total + out
    return total
