"""Halo exchange with interior/boundary overlap (paper §3.2, Figure 3).

Two schedules over the same decomposition:

- ``two_phase``  — the paper's MPI+OpenMP baseline: exchange ALL halos, then
  compute the whole block. The compute depends on every halo, so communication
  serializes with computation (fork-join / "two-phase programming").

- ``hdot``       — the paper's technique: the local block is over-decomposed
  into interior + boundary subdomains. Boundary strips are the only consumers
  of the halo ppermutes, so the (much larger) interior compute is independent
  of communication and XLA's async latency-hiding scheduler overlaps them —
  the SPMD analogue of OmpSs-2 tasks with fine-grained `inout(subdomain)`
  dependencies plus TAMPI-style asynchronous communication.

The hdot schedule over-decomposes the interior into ``subdomains`` chunk
tasks, each reading ONLY its slice of the source (plus `width` ghost rows), so
boundary strips are computed exactly once and the scheduler sees several
independent interior tasks to hide the exchange behind.

For multi-step solvers, :func:`halo_scan_nd` is a double-buffered driver: the
halos for step k+1 ride a ppermute issued as soon as step k's boundary strips
are done — i.e. the exchange for the NEXT step is in flight while the CURRENT
step's interior chunks compute, removing the per-step comm/compute dependency
chain entirely (one pipeline-fill exchange at the start is the only exposed
latency; the drain step is peeled, so no dead final exchange is issued).

The machinery is N-DIMENSIONAL: ``axes`` is a tuple of ``(axis_name, dim)``
pairs — one per decomposed array dim — and the same scheme recurses over any
number of mesh axes (paper §3: ONE partition function, applied at process
level and again at task level, at every depth of the hierarchy):

  * :func:`exchange_halo_nd` moves each axis's face slab (one ppermute pair
    per axis, corner-free — star stencils only),
  * :func:`stencil_with_halo_nd` splits the block into 2·N boundary-face
    tasks plus an N-D interior chunk grid cut by the SAME ``decompose_grid``
    scheme used at process level (via :func:`repro.core.domain.interior_boxes`),
  * :func:`halo_scan_nd` double-buffers ALL axes' exchanges behind the
    interior compute, stitching each axis's outgoing edges from the face
    outputs alone so every ppermute departs before any interior chunk runs.

The N-D family additionally takes ``weights=`` — per-dim explicit chunk
extents (the canonical cuts from :func:`repro.core.domain.interior_cuts`) —
so a measured-cost re-partition produces UNEVEN interior chunk grids while
the onion face partition (and thus the ppermute schedule) is untouched: the
faces depend only on `width`, never on where the interior is cut.

The 1-D (``halo_scan``/``stencil_hdot``/...) and 2-D (``*_2d``) entry points
are DEPRECATED thin aliases of the N-D implementation, kept for their
ergonomic signatures (explicit ``lo/hi`` halos in 1-D; the flat four-halo
tuple in 2-D); new code should spell the decomposition once, as
``axes=((axis_name, dim), ...)``.

All functions run inside ``shard_map`` bodies; `axis_name` names the mesh axis
that carries the process-level domain decomposition for `dim`.
"""
from __future__ import annotations

import warnings
from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.domain import interior_boxes

# One decomposed dim: (mesh_axis_name, array_dim).
Axes = Sequence[Tuple[str, int]]
Decomp = Axes  # deprecated alias, pre-unification spelling

_DEPRECATION_WARNED: set = set()


def _warn_deprecated(name: str, repl: str) -> None:
    """Once-per-process deprecation note for the pre-N-D entry points."""
    if name in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(name)
    warnings.warn(
        f"{name} is a deprecated alias; use {repl} with "
        f"axes=((axis_name, dim), ...)", DeprecationWarning, stacklevel=3)


def _edge(u: jax.Array, dim: int, side: str, width: int) -> jax.Array:
    n = u.shape[dim]
    if side == "lo":
        return lax.slice_in_dim(u, 0, width, axis=dim)
    return lax.slice_in_dim(u, n - width, n, axis=dim)


def exchange_edges(lo_edge: jax.Array, hi_edge: jax.Array, axis_name: str,
                   periodic: bool = False) -> Tuple[jax.Array, jax.Array]:
    """ppermute pre-sliced edge strips; returns (lo_halo, hi_halo).

    The lo halo is the PREVIOUS rank's hi edge (sent "forward"), the hi halo
    the NEXT rank's lo edge (sent "backward"). Taking the edges as arguments
    (instead of slicing internally) lets pipelined callers hand over freshly
    computed boundary strips, so the ppermute depends only on those strips —
    not on the assembled block — and can launch while interior tasks run.

    Non-periodic edge shards receive zeros (ppermute semantics), matching the
    paper's `isBoundary` gating — the zero halo is masked out by callers that
    use boundary conditions.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        if periodic:  # wrap around to own edges
            return hi_edge, lo_edge
        return jnp.zeros_like(hi_edge), jnp.zeros_like(lo_edge)
    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
    else:
        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i, i - 1) for i in range(1, n)]
    lo_halo = lax.ppermute(hi_edge, axis_name, fwd)
    hi_halo = lax.ppermute(lo_edge, axis_name, bwd)
    return lo_halo, hi_halo


def exchange_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (lo_halo, hi_halo): the neighbor edges this shard receives."""
    return exchange_edges(_edge(u, dim, "lo", width), _edge(u, dim, "hi", width),
                          axis_name, periodic)


def pad_with_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> jax.Array:
    """Two-phase building block: concat [lo_halo, u, hi_halo] along `dim`."""
    lo, hi = exchange_halo(u, axis_name, width, dim, periodic)
    return jnp.concatenate([lo, u, hi], axis=dim)


# --------------------------------------------------------------------------
# N-D core — corner-free multi-axis pipelining.
#
# `stencil_fn(padded)` consumes a block padded by `width` ghost cells on BOTH
# ends of EVERY dim in `dims` and must return the updated un-padded block.
# "Star"-shaped stencils only: corners between two decomposed dims are never
# exchanged (sufficient for the paper's Heat2D 5-point and CREAMS
# per-direction WENO stencils; HPCCG's 27-point corner couplings ride the
# sequential face-message chain in core/stencil.py instead).
#
# Partition of a block with extents (n_0 .. n_{N-1}) along the decomposed
# dims ("onion" faces — the 2-D strips generalized):
#   face (k, lo/hi) owns  dims j<k: the interior range [w, n_j - w)
#                         dim  k  : [0, w)  /  [n_k - w, n_k)
#                         dims j>k: the full extent [0, n_j)
#   interior: [w, n_j - w) on every decomposed dim, cut into a grid of chunk
#   tasks by `interior_boxes` — the process-level partition scheme reused at
#   task level, per the paper.
# Face (k, ·) consumes ONLY axis k's halo plus restricted slices of the
# later axes' halos (zero in the corner ghosts, which star stencils never
# read), so each halo ppermute pair has exactly two consumer tasks.
# --------------------------------------------------------------------------

def _sl(u: jax.Array, dim: int, a: int, b: int) -> jax.Array:
    return lax.slice_in_dim(u, a, b, axis=dim)


def _norm_subn(subdomains, n: int) -> Tuple[int, ...]:
    """Grainsize knob: an int means the same chunk count on every dim."""
    if isinstance(subdomains, int):
        return (subdomains,) * n
    t = tuple(subdomains)
    if len(t) != n:
        raise ValueError(
            f"subdomains={subdomains!r} has {len(t)} entries but the "
            f"decomposition is {n}-dimensional; pass an int or one chunk "
            f"count per dim")
    return t


def _norm_sub2(subdomains) -> Tuple[int, int]:
    return _norm_subn(subdomains, 2)


def exchange_halo_nd(u: jax.Array, axes: Axes, width: int,
                     periodic: bool = False
                     ) -> List[Tuple[jax.Array, jax.Array]]:
    """One ppermute pair per decomposed axis; returns [(lo_k, hi_k), ...] in
    `axes` order. Corner ghosts are NOT exchanged."""
    return [exchange_halo(u, a, width, d, periodic) for a, d in axes]


def pad_with_halo_nd(u: jax.Array, halos, width: int,
                     dims: Sequence[int]) -> jax.Array:
    """Assemble the corner-free padded block: face halos on every decomposed
    dim, ZEROS in the corner ghosts (star stencils never read them)."""
    out = u
    for k in reversed(range(len(dims))):
        lo, hi = halos[k]
        pads = [(0, 0)] * u.ndim
        for j in range(k + 1, len(dims)):
            pads[dims[j]] = (width, width)
        lo = jnp.pad(lo, pads)
        hi = jnp.pad(hi, pads)
        out = jnp.concatenate([lo, out, hi], axis=dims[k])
    return out


def _face_src_nd(u: jax.Array, halos, k: int, side: str, width: int,
                 dims: Sequence[int]) -> jax.Array:
    """Ghost-extended source for face (k, side) — the ONLY consumer of axis
    k's `side` halo. Along earlier dims the face outputs the interior range,
    so u's own cells are the ghosts (full extent, no halo needed); along
    later dims the face spans the full extent, so their halos are stitched
    in, restricted to this face's cells and zero-padded into the corners."""
    w = width
    dk = dims[k]
    nk = u.shape[dk]
    lo_k, hi_k = halos[k]
    if side == "lo":
        cells = (0, 2 * w)          # the u-cells adjacent to this face
        src = jnp.concatenate([lo_k, _sl(u, dk, *cells)], axis=dk)
        zk = (w, 0)                 # where axis k's halo sits inside src
    else:
        cells = (nk - 2 * w, nk)
        src = jnp.concatenate([_sl(u, dk, *cells), hi_k], axis=dk)
        zk = (0, w)
    for j in range(k + 1, len(dims)):
        lo_j, hi_j = halos[j]

        def clip(h):
            h = _sl(h, dk, *cells)
            pads = [(0, 0)] * u.ndim
            pads[dk] = zk                       # corner with axis k: zeros
            for jp in range(k + 1, j):
                pads[dims[jp]] = (width, width)  # corner with axis jp: zeros
            return jnp.pad(h, pads)

        src = jnp.concatenate([clip(lo_j), src, clip(hi_j)], axis=dims[j])
    return src


def _faces_nd(u: jax.Array, halos,
              stencil_fn: Callable[[jax.Array], jax.Array], width: int,
              dims: Sequence[int]) -> List[Tuple[jax.Array, jax.Array]]:
    """The 2·N boundary-face tasks — the only consumers of the halos."""
    return [(stencil_fn(_face_src_nd(u, halos, k, "lo", width, dims)),
             stencil_fn(_face_src_nd(u, halos, k, "hi", width, dims)))
            for k in range(len(dims))]


def _chunk_grid_nd(ext: Sequence[int], width: int,
                   subdomains: Tuple[int, ...], weights) -> Tuple[list, list]:
    """Resolve the interior chunk grid: per-dim chunk counts (`subdomains`
    clamped so uniform chunks stay >= 2*width) plus the optional measured-cost
    cut. `weights` is None or one entry per dim — None (uniform) or the
    explicit chunk extents from :func:`repro.core.domain.interior_cuts`; an
    extents entry fixes that dim's chunk count and must sum to the interior
    extent."""
    w = width
    ks = [max(1, min(k, (n - 2 * w) // max(1, 2 * w)))  # keep chunks >= 2w
          for k, n in zip(subdomains, ext)]
    if weights is None:
        return ks, None
    wts = list(weights)
    if len(wts) != len(ext):
        raise ValueError(
            f"weights names {len(wts)} dims but the decomposition is "
            f"{len(ext)}-dimensional — one entry (or None) per dim required")
    for lvl, entry in enumerate(wts):
        if entry is None:
            continue
        entry = tuple(int(v) for v in entry)
        inner = max(0, ext[lvl] - 2 * w)
        if sum(entry) != inner or any(v < 0 for v in entry):
            raise ValueError(
                f"weights[{lvl}]={entry} must be non-negative chunk extents "
                f"summing to the interior extent {inner} (use "
                f"repro.core.domain.interior_cuts to canonicalize measured "
                f"costs)")
        wts[lvl] = entry
        ks[lvl] = len(entry)  # an explicit cut fixes the chunk count
    return ks, wts


def _interior_chunks_nd(u: jax.Array,
                        stencil_fn: Callable[[jax.Array], jax.Array],
                        width: int, dims: Sequence[int],
                        subdomains: Tuple[int, ...],
                        weights=None) -> jax.Array:
    """Interior cells [w, n-w) per decomposed dim as an N-D grid of
    independent chunk tasks, cut by `interior_boxes` — the process-level
    partition scheme reused at task level. A chunk reads only its subdomain
    plus `width` ghosts, so chunks are disjoint work the latency-hiding
    scheduler interleaves with every axis's ppermutes. `weights` (per-dim
    explicit chunk extents) makes the grid UNEVEN — the measured-cost re-cut —
    without touching the face partition."""
    w = width
    ext = [u.shape[d] for d in dims]
    ks, wts = _chunk_grid_nd(ext, w, subdomains, weights)
    boxes = interior_boxes(ext, w, ks, wts)  # row-major over the ks grid
    outs = []
    for b in boxes:
        src = u
        for lvl, d in enumerate(dims):
            src = _sl(src, d, b.start[lvl] - w, b.stop[lvl] + w)
        outs.append(stencil_fn(src))
    for lvl in range(len(ks) - 1, -1, -1):  # row-major -> nested concat
        k = ks[lvl]
        outs = [outs[i] if k == 1
                else jnp.concatenate(outs[i:i + k], axis=dims[lvl])
                for i in range(0, len(outs), k)]
    return outs[0]


def _assemble_nd(faces, interior: jax.Array,
                 dims: Sequence[int]) -> jax.Array:
    """Wrap the interior chunk grid in the face outputs, innermost dim out."""
    out = interior
    for k in reversed(range(len(dims))):
        lo, hi = faces[k]
        out = jnp.concatenate([lo, out, hi], axis=dims[k])
    return out


def stencil_with_halo_nd(u: jax.Array, halos,
                         stencil_fn: Callable[[jax.Array], jax.Array],
                         width: int, dims: Sequence[int],
                         subdomains=2, weights=None) -> jax.Array:
    """Communication-free half of the N-D hdot schedule: apply `stencil_fn`
    to a block whose 2·N face halos were ALREADY received (e.g. pipelined by
    halo_scan_nd or a solver carrying halos across iterations)."""
    dims = tuple(dims)
    subdomains = _norm_subn(subdomains, len(dims))
    if any(u.shape[d] < 4 * width for d in dims):  # degenerate: no interior
        return stencil_fn(pad_with_halo_nd(u, halos, width, dims))
    faces = _faces_nd(u, halos, stencil_fn, width, dims)
    interior = _interior_chunks_nd(u, stencil_fn, width, dims, subdomains,
                                   weights)
    return _assemble_nd(faces, interior, dims)


def stencil_two_phase_nd(u: jax.Array,
                         stencil_fn: Callable[[jax.Array], jax.Array],
                         axes: Axes, width: int,
                         periodic: bool = False) -> jax.Array:
    """comm(all axes); barrier; compute(whole block) — paper Code 2."""
    dims = tuple(d for _, d in axes)
    halos = exchange_halo_nd(u, axes, width, periodic)
    return stencil_fn(pad_with_halo_nd(u, halos, width, dims))


def stencil_hdot_nd(u: jax.Array,
                    stencil_fn: Callable[[jax.Array], jax.Array],
                    axes: Axes, width: int, periodic: bool = False,
                    subdomains=2, weights=None) -> jax.Array:
    """N-D interior/boundary over-decomposition (paper Code 4): 2·N face
    tasks consume the N ppermute pairs; the interior chunk grid depends only
    on `u`. Numerics identical to the two-phase schedule (asserted in tests).
    """
    dims = tuple(d for _, d in axes)
    if any(u.shape[d] < 4 * width for d in dims):
        return stencil_two_phase_nd(u, stencil_fn, axes, width, periodic)
    halos = exchange_halo_nd(u, axes, width, periodic)
    return stencil_with_halo_nd(u, halos, stencil_fn, width, dims, subdomains,
                                weights)


def stencil_apply_nd(u: jax.Array,
                     stencil_fn: Callable[[jax.Array], jax.Array],
                     axes: Axes, width: int, periodic: bool = False,
                     mode: str = "hdot", subdomains=2,
                     weights=None) -> jax.Array:
    if mode == "hdot":
        return stencil_hdot_nd(u, stencil_fn, axes, width, periodic,
                               subdomains, weights)
    if mode in ("none", "two_phase"):
        return stencil_two_phase_nd(u, stencil_fn, axes, width, periodic)
    raise ValueError(f"unknown overlap mode {mode!r}")


def halo_scan_nd(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                 axes: Axes, width: int, steps: int,
                 periodic: bool = False, mode: str = "hdot", subdomains=2,
                 step_out_fn: Optional[Callable[[jax.Array, jax.Array],
                                                jax.Array]] = None,
                 unroll: int = 1, peel: bool = True, weights=None
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Double-buffered multi-step stencil driver on an N-D process mesh.

    In hdot mode the scan carry is (block, per-axis halos): the halos for
    step k arrive with the carry, so the body can (1) finish step k's 2·N
    boundary faces — the only halo consumers; (2) IMMEDIATELY launch EVERY
    axis's ppermute pair for step k+1 (the new block's axis-k edges are
    stitched from the face outputs alone, corner-free); (3) only then chew
    through step k's interior chunk grid. All N exchanges are therefore
    always in flight behind the interior compute; the only exposed latency
    is the pipeline-fill exchange before the scan.

    The final step is PEELED out of the scan (pipeline drain): the in-body
    exchange would feed a step that never runs, so the scan covers steps-1
    trips and the last step consumes its carried halos without launching new
    ppermutes — N dead exchange pairs per solve saved (``peel=False`` keeps
    the old drain-in-scan lowering; regression tests count the ppermutes).

    `step_out_fn(u_new, u_old)` optionally produces a per-step output (e.g. a
    residual); its stacked results are returned as the second element (None
    when not provided). Numerics are identical to `steps` iterated calls of
    :func:`stencil_apply_nd` — asserted in tests. `unroll` is forwarded to
    lax.scan (the HLO-inspection tests unroll fully so every exchange is a
    countable op definition). `weights` (per-dim explicit chunk extents from
    :func:`repro.core.domain.interior_cuts`) cuts the interior chunk grid
    unevenly — the face partition and the ppermute schedule are unchanged, so
    a measured-cost re-cut never alters the communication shape.
    """
    axes = tuple((a, d) for a, d in axes)
    dims = tuple(d for _, d in axes)
    w = width
    ext = tuple(u.shape[d] for d in dims)
    if mode != "hdot" or any(n < 4 * w for n in ext) or steps < 1:
        # two-phase baseline (or degenerate block / empty scan, which keeps
        # the length-0 stacked-outs contract): plain comm->compute scan
        def body(u, _):
            u_new = stencil_apply_nd(u, stencil_fn, axes, w, periodic,
                                     mode, subdomains, weights)
            return u_new, step_out_fn(u_new, u) if step_out_fn else None
        return lax.scan(body, u, None, length=steps, unroll=unroll)

    subdomains = _norm_subn(subdomains, len(dims))

    def exchange_from_faces(faces):
        # The new block's axis-k edges, stitched from face outputs alone —
        # still no interior dependency, so every pair departs before any
        # interior chunk is touched. Axis k's edge spans the full extent of
        # every other dim: the earlier axes' faces contribute their first /
        # last `w` cells along dim k (faces of LATER axes never reach the
        # edge region — their dim-k extent is the interior range).
        halos_next = []
        for k, (a, dk) in enumerate(axes):
            lo_e, hi_e = faces[k]
            nk = ext[k]
            for j in reversed(range(k)):
                lo_j, hi_j = faces[j]
                lo_e = jnp.concatenate(
                    [_sl(lo_j, dk, 0, w), lo_e, _sl(hi_j, dk, 0, w)],
                    axis=dims[j])
                hi_e = jnp.concatenate(
                    [_sl(lo_j, dk, nk - w, nk), hi_e,
                     _sl(hi_j, dk, nk - w, nk)], axis=dims[j])
            halos_next.append(exchange_edges(lo_e, hi_e, a, periodic))
        return halos_next

    def body(carry, _):
        u, halos = carry
        faces = _faces_nd(u, halos, stencil_fn, w, dims)
        halos_next = exchange_from_faces(faces)
        interior = _interior_chunks_nd(u, stencil_fn, w, dims, subdomains,
                                       weights)
        u_new = _assemble_nd(faces, interior, dims)
        out = step_out_fn(u_new, u) if step_out_fn else None
        return (u_new, halos_next), out

    halos0 = exchange_halo_nd(u, axes, w, periodic)  # pipeline fill
    if not peel:
        (u, _), outs = lax.scan(body, (u, halos0), None, length=steps,
                                unroll=unroll)
        return u, outs
    (u, halos), outs = lax.scan(body, (u, halos0), None, length=steps - 1,
                                unroll=unroll)
    # Peeled drain: the last step consumes its halos, launches nothing.
    u_new = stencil_with_halo_nd(u, halos, stencil_fn, w, dims, subdomains,
                                 weights)
    if step_out_fn is not None:
        outs = jax.tree.map(
            lambda s, o: jnp.concatenate([s, o[None]], axis=0), outs,
            step_out_fn(u_new, u))
    return u_new, outs


# --------------------------------------------------------------------------
# 1-D entry points — DEPRECATED thin aliases of the N-D core, kept for the
# explicit (lo_halo, hi_halo) signatures older callers use. New code spells
# the decomposition as axes=((axis_name, dim),). `stencil_fn(padded)`
# consumes a block padded by `width` on both ends of `dim` only.
# --------------------------------------------------------------------------

def stencil_two_phase(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                      axis_name: str, width: int, dim: int,
                      periodic: bool = False) -> jax.Array:
    """Deprecated alias: comm(D); barrier; compute(D) — paper Code 2."""
    _warn_deprecated("stencil_two_phase", "stencil_two_phase_nd")
    return stencil_two_phase_nd(u, stencil_fn, ((axis_name, dim),), width,
                                periodic)


def stencil_with_halo(u: jax.Array, lo_halo: jax.Array, hi_halo: jax.Array,
                      stencil_fn: Callable[[jax.Array], jax.Array],
                      width: int, dim: int, subdomains: int = 4) -> jax.Array:
    """Deprecated alias of :func:`stencil_with_halo_nd` (halos spelled as the
    flat (lo, hi) pair): apply `stencil_fn` to a block whose halos were
    ALREADY received. Boundary strips consume the halos; the interior is
    over-decomposed into `subdomains` chunks."""
    _warn_deprecated("stencil_with_halo", "stencil_with_halo_nd")
    return stencil_with_halo_nd(u, [(lo_halo, hi_halo)], stencil_fn, width,
                                (dim,), (subdomains,))


def stencil_hdot(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                 axis_name: str, width: int, dim: int,
                 periodic: bool = False,
                 subdomains: int = 4) -> jax.Array:
    """Deprecated alias of :func:`stencil_hdot_nd`, one mesh axis."""
    _warn_deprecated("stencil_hdot", "stencil_hdot_nd")
    return stencil_hdot_nd(u, stencil_fn, ((axis_name, dim),), width,
                           periodic, (subdomains,))


def stencil_apply(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                  axis_name: str, width: int, dim: int,
                  periodic: bool = False, mode: str = "hdot",
                  subdomains: int = 4) -> jax.Array:
    """Deprecated alias of :func:`stencil_apply_nd`, one mesh axis."""
    _warn_deprecated("stencil_apply", "stencil_apply_nd")
    return stencil_apply_nd(u, stencil_fn, ((axis_name, dim),), width,
                            periodic, mode, (subdomains,))


def halo_scan(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
              axis_name: str, width: int, dim: int, steps: int,
              periodic: bool = False, mode: str = "hdot",
              subdomains: int = 4,
              step_out_fn: Optional[Callable[[jax.Array, jax.Array], jax.Array]]
              = None, unroll: int = 1,
              peel: bool = True) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Deprecated alias: double-buffered multi-step driver on one mesh axis
    (see :func:`halo_scan_nd` for the schedule)."""
    _warn_deprecated("halo_scan", "halo_scan_nd")
    return halo_scan_nd(u, stencil_fn, ((axis_name, dim),), width, steps,
                        periodic, mode, (subdomains,), step_out_fn, unroll,
                        peel)


# --------------------------------------------------------------------------
# 2-D (rows x cols) entry points — DEPRECATED thin aliases of the N-D core,
# kept for the flat four-halo tuple signature. New code spells the
# decomposition as axes=((row_axis, dim0), (col_axis, dim1)).
# `stencil_fn(padded)` consumes a block padded by `width` on both ends of
# BOTH dims in `dims`.
# --------------------------------------------------------------------------

def _halos2(halos):
    lo0, hi0, lo1, hi1 = halos
    return ((lo0, hi0), (lo1, hi1))


def exchange_halo_2d(u: jax.Array, axis_names: Tuple[str, str], width: int,
                     dims: Tuple[int, int], periodic: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Deprecated alias: combined edge exchange on both mesh axes (one
    ppermute pair per axis). Returns (lo0, hi0, lo1, hi1); corner ghosts are
    NOT exchanged."""
    _warn_deprecated("exchange_halo_2d", "exchange_halo_nd")
    (lo0, hi0), (lo1, hi1) = exchange_halo_nd(
        u, tuple(zip(axis_names, dims)), width, periodic)
    return lo0, hi0, lo1, hi1


def pad_with_halo_2d(u: jax.Array, halos, width: int, dims: Tuple[int, int]
                     ) -> jax.Array:
    """Deprecated alias: assemble the corner-free padded block — halos on the
    four faces, ZEROS in the (2*width)^2 corners (star stencils never read
    them)."""
    _warn_deprecated("pad_with_halo_2d", "pad_with_halo_nd")
    return pad_with_halo_nd(u, _halos2(halos), width, dims)


def stencil_two_phase_2d(u: jax.Array,
                         stencil_fn: Callable[[jax.Array], jax.Array],
                         axis_names: Tuple[str, str], width: int,
                         dims: Tuple[int, int], periodic: bool = False
                         ) -> jax.Array:
    """Deprecated alias: comm(both axes); barrier; compute(whole block)."""
    _warn_deprecated("stencil_two_phase_2d", "stencil_two_phase_nd")
    return stencil_two_phase_nd(u, stencil_fn, tuple(zip(axis_names, dims)),
                                width, periodic)


def stencil_with_halo_2d(u: jax.Array, halos,
                         stencil_fn: Callable[[jax.Array], jax.Array],
                         width: int, dims: Tuple[int, int],
                         subdomains=(2, 2)) -> jax.Array:
    """Deprecated alias of :func:`stencil_with_halo_nd` (halos spelled as the
    flat four-tuple): apply `stencil_fn` to a block whose four face halos
    were ALREADY received."""
    _warn_deprecated("stencil_with_halo_2d", "stencil_with_halo_nd")
    return stencil_with_halo_nd(u, _halos2(halos), stencil_fn, width, dims,
                                _norm_sub2(subdomains))


def stencil_hdot_2d(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                    axis_names: Tuple[str, str], width: int,
                    dims: Tuple[int, int], periodic: bool = False,
                    subdomains=(2, 2)) -> jax.Array:
    """Deprecated alias of :func:`stencil_hdot_nd`: four strip tasks consume
    the two ppermute pairs; the (kr x kc) interior grid depends only on u."""
    _warn_deprecated("stencil_hdot_2d", "stencil_hdot_nd")
    return stencil_hdot_nd(u, stencil_fn, tuple(zip(axis_names, dims)), width,
                           periodic, _norm_sub2(subdomains))


def stencil_apply_2d(u: jax.Array,
                     stencil_fn: Callable[[jax.Array], jax.Array],
                     axis_names: Tuple[str, str], width: int,
                     dims: Tuple[int, int], periodic: bool = False,
                     mode: str = "hdot", subdomains=(2, 2)) -> jax.Array:
    """Deprecated alias of :func:`stencil_apply_nd`, two mesh axes."""
    _warn_deprecated("stencil_apply_2d", "stencil_apply_nd")
    return stencil_apply_nd(u, stencil_fn, tuple(zip(axis_names, dims)),
                            width, periodic, mode, _norm_sub2(subdomains))


def halo_scan_2d(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                 axis_names: Tuple[str, str], width: int,
                 dims: Tuple[int, int], steps: int, periodic: bool = False,
                 mode: str = "hdot", subdomains=(2, 2),
                 step_out_fn: Optional[Callable[[jax.Array, jax.Array],
                                                jax.Array]] = None,
                 unroll: int = 1, peel: bool = True
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Deprecated alias: double-buffered multi-step driver on a (rows x cols)
    mesh (see :func:`halo_scan_nd` for the schedule; both axes' exchanges
    ride behind the interior compute, and the drain step is peeled)."""
    _warn_deprecated("halo_scan_2d", "halo_scan_nd")
    return halo_scan_nd(u, stencil_fn, tuple(zip(axis_names, dims)), width,
                        steps, periodic, mode, _norm_sub2(subdomains),
                        step_out_fn, unroll, peel)


def multi_dim_stencil(u: jax.Array,
                      per_dim_fn: Callable[[jax.Array, int], jax.Array],
                      decomp: Sequence[Tuple[int, Optional[str]]],
                      width: int, periodic: bool = False,
                      mode: str = "hdot") -> jax.Array:
    """Apply a direction-split stencil along several decomposed dims (the
    CREAMS pattern: euler_LLF_x/y/z are separate per-direction stencils whose
    results sum). `decomp` lists (dim, mesh_axis_or_None); un-sharded dims use
    a local pad."""
    total = None
    for dim, axis_name in decomp:
        fn = partial(per_dim_fn, dim=dim)
        if axis_name is None:
            if periodic:
                padded = jnp.concatenate(
                    [_edge(u, dim, "hi", width), u, _edge(u, dim, "lo", width)], axis=dim)
            else:
                pads = [(0, 0)] * u.ndim
                pads[dim] = (width, width)
                padded = jnp.pad(u, pads)
            out = fn(padded)
        else:
            out = stencil_apply_nd(u, fn, ((axis_name, dim),), width,
                                   periodic, mode, (4,))
        total = out if total is None else total + out
    return total
