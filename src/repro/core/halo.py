"""Halo exchange with interior/boundary overlap (paper §3.2, Figure 3).

Two schedules over the same decomposition:

- ``two_phase``  — the paper's MPI+OpenMP baseline: exchange ALL halos, then
  compute the whole block. The compute depends on every halo, so communication
  serializes with computation (fork-join / "two-phase programming").

- ``hdot``       — the paper's technique: the local block is over-decomposed
  into interior + boundary subdomains. Boundary strips are the only consumers
  of the halo ppermutes, so the (much larger) interior compute is independent
  of communication and XLA's async latency-hiding scheduler overlaps them —
  the SPMD analogue of OmpSs-2 tasks with fine-grained `inout(subdomain)`
  dependencies plus TAMPI-style asynchronous communication.

All functions run inside ``shard_map`` bodies; `axis_name` names the mesh axis
that carries the process-level domain decomposition for `dim`.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _edge(u: jax.Array, dim: int, side: str, width: int) -> jax.Array:
    n = u.shape[dim]
    if side == "lo":
        return lax.slice_in_dim(u, 0, width, axis=dim)
    return lax.slice_in_dim(u, n - width, n, axis=dim)


def exchange_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (lo_halo, hi_halo): the neighbor edges this shard receives.

    Non-periodic edge shards receive zeros (ppermute semantics), matching the
    paper's `isBoundary` gating — the zero halo is masked out by callers that
    use boundary conditions.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        if periodic:  # wrap around to own edges
            return _edge(u, dim, "hi", width), _edge(u, dim, "lo", width)
        z = jnp.zeros_like(_edge(u, dim, "lo", width))
        return z, z
    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
    else:
        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i, i - 1) for i in range(1, n)]
    # lo halo comes from the previous rank's hi edge (sent "forward"),
    # hi halo from the next rank's lo edge (sent "backward").
    lo_halo = lax.ppermute(_edge(u, dim, "hi", width), axis_name, fwd)
    hi_halo = lax.ppermute(_edge(u, dim, "lo", width), axis_name, bwd)
    return lo_halo, hi_halo


def pad_with_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> jax.Array:
    """Two-phase building block: concat [lo_halo, u, hi_halo] along `dim`."""
    lo, hi = exchange_halo(u, axis_name, width, dim, periodic)
    return jnp.concatenate([lo, u, hi], axis=dim)


# --------------------------------------------------------------------------
# Stencil application schedules.
#
# `stencil_fn(padded)` consumes a block padded by `width` ghost cells on BOTH
# ends of `dim` and must return the updated un-padded block (shape of the
# interior of `padded` along `dim`). "Star"-shaped stencils only: corners
# between two decomposed dims are not exchanged (sufficient for the paper's
# Heat2D 5-point and CREAMS per-direction WENO stencils).
# --------------------------------------------------------------------------

def stencil_two_phase(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                      axis_name: str, width: int, dim: int,
                      periodic: bool = False) -> jax.Array:
    """comm(D); barrier; compute(D) — paper Code 2."""
    padded = pad_with_halo(u, axis_name, width, dim, periodic)
    return stencil_fn(padded)


def stencil_hdot(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                 axis_name: str, width: int, dim: int,
                 periodic: bool = False,
                 subdomains: int = 4) -> jax.Array:
    """Interior/boundary over-decomposition (paper Code 4).

    The interior result depends only on `u`; the two boundary strips are the
    sole consumers of the halo ppermutes. `subdomains` controls how much
    interior work is available to hide the exchange (>=2 interior chunks keeps
    the scheduler's window open; chunks are concatenated back, so numerics are
    identical to the two-phase schedule — asserted in tests).
    """
    n = u.shape[dim]
    if n < 4 * width:  # degenerate block: no interior to overlap with
        return stencil_two_phase(u, stencil_fn, axis_name, width, dim, periodic)

    lo_halo, hi_halo = exchange_halo(u, axis_name, width, dim, periodic)

    # Interior "tasks": cells [width, n-width) need no halo. Over-decompose
    # them with the same scheme used across shards (decompose_grid in 1-D).
    interior_src = u  # full block provides ghost context for interior cells
    interior = stencil_fn(interior_src)          # updates cells [width, n-width)
    # Boundary "tasks": the only consumers of the received halos.
    lo_src = jnp.concatenate(
        [lo_halo, lax.slice_in_dim(u, 0, 2 * width, axis=dim)], axis=dim)
    hi_src = jnp.concatenate(
        [lax.slice_in_dim(u, n - 2 * width, n, axis=dim), hi_halo], axis=dim)
    lo_out = stencil_fn(lo_src)                  # updates cells [0, width)
    hi_out = stencil_fn(hi_src)                  # updates cells [n-width, n)

    # Optional further over-decomposition of the interior into `subdomains`
    # chunks: not needed for correctness — XLA already sees one large
    # independent region — but mirrors the paper's task granularity knob.
    del subdomains
    return jnp.concatenate([lo_out, interior, hi_out], axis=dim)


def stencil_apply(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                  axis_name: str, width: int, dim: int,
                  periodic: bool = False, mode: str = "hdot",
                  subdomains: int = 4) -> jax.Array:
    if mode == "hdot":
        return stencil_hdot(u, stencil_fn, axis_name, width, dim, periodic, subdomains)
    if mode in ("none", "two_phase"):
        return stencil_two_phase(u, stencil_fn, axis_name, width, dim, periodic)
    raise ValueError(f"unknown overlap mode {mode!r}")


def multi_dim_stencil(u: jax.Array,
                      per_dim_fn: Callable[[jax.Array, int], jax.Array],
                      decomp: Sequence[Tuple[int, Optional[str]]],
                      width: int, periodic: bool = False,
                      mode: str = "hdot") -> jax.Array:
    """Apply a direction-split stencil along several decomposed dims (the
    CREAMS pattern: euler_LLF_x/y/z are separate per-direction stencils whose
    results sum). `decomp` lists (dim, mesh_axis_or_None); un-sharded dims use
    a local pad."""
    total = None
    for dim, axis_name in decomp:
        fn = partial(per_dim_fn, dim=dim)
        if axis_name is None:
            if periodic:
                padded = jnp.concatenate(
                    [_edge(u, dim, "hi", width), u, _edge(u, dim, "lo", width)], axis=dim)
            else:
                pads = [(0, 0)] * u.ndim
                pads[dim] = (width, width)
                padded = jnp.pad(u, pads)
            out = fn(padded)
        else:
            out = stencil_apply(u, fn, axis_name, width, dim, periodic, mode)
        total = out if total is None else total + out
    return total
