"""Halo exchange with interior/boundary overlap (paper §3.2, Figure 3).

Two schedules over the same decomposition:

- ``two_phase``  — the paper's MPI+OpenMP baseline: exchange ALL halos, then
  compute the whole block. The compute depends on every halo, so communication
  serializes with computation (fork-join / "two-phase programming").

- ``hdot``       — the paper's technique: the local block is over-decomposed
  into interior + boundary subdomains. Boundary strips are the only consumers
  of the halo ppermutes, so the (much larger) interior compute is independent
  of communication and XLA's async latency-hiding scheduler overlaps them —
  the SPMD analogue of OmpSs-2 tasks with fine-grained `inout(subdomain)`
  dependencies plus TAMPI-style asynchronous communication.

The hdot schedule over-decomposes the interior into ``subdomains`` chunk
tasks, each reading ONLY its slice of the source (plus `width` ghost rows), so
boundary strips are computed exactly once and the scheduler sees several
independent interior tasks to hide the exchange behind.

For multi-step solvers, :func:`halo_scan` is a double-buffered driver: the
halos for step k+1 ride a ppermute issued as soon as step k's boundary strips
are done — i.e. the exchange for the NEXT step is in flight while the CURRENT
step's interior chunks compute, removing the per-step comm/compute dependency
chain entirely (one pipeline-fill exchange at the start is the only exposed
latency; the drain step is peeled, so no dead final exchange is issued).

The ``*_2d`` family generalizes the whole scheme to a (rows x cols) process
mesh: :func:`exchange_halo_2d` moves both axes' face strips (corner-free —
star stencils only), :func:`stencil_with_halo_2d` splits the block into four
boundary-strip tasks plus a 2-D interior chunk grid cut by the SAME
``decompose_grid`` scheme used at process level, and :func:`halo_scan_2d`
double-buffers both axes' exchanges behind the interior compute.

All functions run inside ``shard_map`` bodies; `axis_name` names the mesh axis
that carries the process-level domain decomposition for `dim`.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.domain import interior_boxes


def _edge(u: jax.Array, dim: int, side: str, width: int) -> jax.Array:
    n = u.shape[dim]
    if side == "lo":
        return lax.slice_in_dim(u, 0, width, axis=dim)
    return lax.slice_in_dim(u, n - width, n, axis=dim)


def exchange_edges(lo_edge: jax.Array, hi_edge: jax.Array, axis_name: str,
                   periodic: bool = False) -> Tuple[jax.Array, jax.Array]:
    """ppermute pre-sliced edge strips; returns (lo_halo, hi_halo).

    The lo halo is the PREVIOUS rank's hi edge (sent "forward"), the hi halo
    the NEXT rank's lo edge (sent "backward"). Taking the edges as arguments
    (instead of slicing internally) lets pipelined callers hand over freshly
    computed boundary strips, so the ppermute depends only on those strips —
    not on the assembled block — and can launch while interior tasks run.

    Non-periodic edge shards receive zeros (ppermute semantics), matching the
    paper's `isBoundary` gating — the zero halo is masked out by callers that
    use boundary conditions.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        if periodic:  # wrap around to own edges
            return hi_edge, lo_edge
        return jnp.zeros_like(hi_edge), jnp.zeros_like(lo_edge)
    if periodic:
        fwd = [(i, (i + 1) % n) for i in range(n)]
        bwd = [(i, (i - 1) % n) for i in range(n)]
    else:
        fwd = [(i, i + 1) for i in range(n - 1)]
        bwd = [(i, i - 1) for i in range(1, n)]
    lo_halo = lax.ppermute(hi_edge, axis_name, fwd)
    hi_halo = lax.ppermute(lo_edge, axis_name, bwd)
    return lo_halo, hi_halo


def exchange_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Returns (lo_halo, hi_halo): the neighbor edges this shard receives."""
    return exchange_edges(_edge(u, dim, "lo", width), _edge(u, dim, "hi", width),
                          axis_name, periodic)


def pad_with_halo(u: jax.Array, axis_name: str, width: int, dim: int,
                  periodic: bool = False) -> jax.Array:
    """Two-phase building block: concat [lo_halo, u, hi_halo] along `dim`."""
    lo, hi = exchange_halo(u, axis_name, width, dim, periodic)
    return jnp.concatenate([lo, u, hi], axis=dim)


# --------------------------------------------------------------------------
# Stencil application schedules.
#
# `stencil_fn(padded)` consumes a block padded by `width` ghost cells on BOTH
# ends of `dim` and must return the updated un-padded block (shape of the
# interior of `padded` along `dim`). "Star"-shaped stencils only: corners
# between two decomposed dims are not exchanged (sufficient for the paper's
# Heat2D 5-point and CREAMS per-direction WENO stencils).
# --------------------------------------------------------------------------

def stencil_two_phase(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                      axis_name: str, width: int, dim: int,
                      periodic: bool = False) -> jax.Array:
    """comm(D); barrier; compute(D) — paper Code 2."""
    padded = pad_with_halo(u, axis_name, width, dim, periodic)
    return stencil_fn(padded)


def _interior_chunks(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                     width: int, dim: int, subdomains: int) -> List[jax.Array]:
    """Interior cells [width, n-width) as up to `subdomains` independent chunk
    tasks (the paper's grainsize knob, Code 4's `for s in subdomains`).

    The chunk covering cells [a, b) reads ONLY u[a-width : b+width] — each
    task's footprint is its subdomain plus `width` ghost cells, so boundary
    strips are never recomputed and the chunks are disjoint work the
    latency-hiding scheduler interleaves with the halo ppermutes."""
    n = u.shape[dim]
    m = n - 2 * width                     # interior cell count
    k = max(1, min(subdomains, m // max(1, 2 * width)))  # keep chunks >= 2*width
    if k == 1:
        return [stencil_fn(u)]           # one interior task, full ghost context
    bounds = [width + (m * t) // k for t in range(k + 1)]
    return [stencil_fn(lax.slice_in_dim(u, a - width, b + width, axis=dim))
            for a, b in zip(bounds[:-1], bounds[1:])]


def _boundary_srcs(u: jax.Array, lo_halo: jax.Array, hi_halo: jax.Array,
                   width: int, dim: int) -> Tuple[jax.Array, jax.Array]:
    n = u.shape[dim]
    lo_src = jnp.concatenate(
        [lo_halo, lax.slice_in_dim(u, 0, 2 * width, axis=dim)], axis=dim)
    hi_src = jnp.concatenate(
        [lax.slice_in_dim(u, n - 2 * width, n, axis=dim), hi_halo], axis=dim)
    return lo_src, hi_src


def stencil_with_halo(u: jax.Array, lo_halo: jax.Array, hi_halo: jax.Array,
                      stencil_fn: Callable[[jax.Array], jax.Array],
                      width: int, dim: int, subdomains: int = 4) -> jax.Array:
    """Communication-free half of the hdot schedule: apply `stencil_fn` to a
    block whose halos were ALREADY received (e.g. pipelined by halo_scan or a
    solver carrying halos across iterations). Boundary strips consume the
    halos; the interior is over-decomposed into `subdomains` chunk tasks."""
    n = u.shape[dim]
    if n < 4 * width:  # degenerate block: no interior to split off
        return stencil_fn(jnp.concatenate([lo_halo, u, hi_halo], axis=dim))
    lo_src, hi_src = _boundary_srcs(u, lo_halo, hi_halo, width, dim)
    lo_out = stencil_fn(lo_src)                  # updates cells [0, width)
    hi_out = stencil_fn(hi_src)                  # updates cells [n-width, n)
    interior = _interior_chunks(u, stencil_fn, width, dim, subdomains)
    return jnp.concatenate([lo_out, *interior, hi_out], axis=dim)


def stencil_hdot(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                 axis_name: str, width: int, dim: int,
                 periodic: bool = False,
                 subdomains: int = 4) -> jax.Array:
    """Interior/boundary over-decomposition (paper Code 4).

    The interior — split into `subdomains` chunk tasks, each reading only its
    own slice plus ghosts — depends only on `u`; the two boundary strips are
    the sole consumers of the halo ppermutes. Chunks are concatenated back, so
    numerics are identical to the two-phase schedule (asserted in tests).
    """
    n = u.shape[dim]
    if n < 4 * width:  # degenerate block: no interior to overlap with
        return stencil_two_phase(u, stencil_fn, axis_name, width, dim, periodic)
    lo_halo, hi_halo = exchange_halo(u, axis_name, width, dim, periodic)
    return stencil_with_halo(u, lo_halo, hi_halo, stencil_fn, width, dim,
                             subdomains)


def stencil_apply(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                  axis_name: str, width: int, dim: int,
                  periodic: bool = False, mode: str = "hdot",
                  subdomains: int = 4) -> jax.Array:
    if mode == "hdot":
        return stencil_hdot(u, stencil_fn, axis_name, width, dim, periodic, subdomains)
    if mode in ("none", "two_phase"):
        return stencil_two_phase(u, stencil_fn, axis_name, width, dim, periodic)
    raise ValueError(f"unknown overlap mode {mode!r}")


def halo_scan(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
              axis_name: str, width: int, dim: int, steps: int,
              periodic: bool = False, mode: str = "hdot",
              subdomains: int = 4,
              step_out_fn: Optional[Callable[[jax.Array, jax.Array], jax.Array]]
              = None, unroll: int = 1,
              peel: bool = True) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Double-buffered multi-step stencil driver (lax.scan over `steps`).

    In hdot mode the scan carry is (block, lo_halo, hi_halo): the halos for
    step k arrive with the carry, so the body can (1) finish step k's boundary
    strips, (2) IMMEDIATELY launch the ppermute that feeds step k+1 — the new
    block's edges are exactly those boundary outputs — and (3) only then chew
    through step k's interior chunk tasks. The exchange for the next step is
    therefore always in flight behind the current step's interior compute; the
    only exposed latency is the single pipeline-fill exchange before the scan.

    The final step is PEELED out of the scan (pipeline drain): the in-body
    exchange would feed a step that never runs, so the scan covers steps-1
    trips and the last step consumes its carried halos without launching a new
    ppermute pair — one dead exchange per solve saved (``peel=False`` keeps
    the old drain-in-scan lowering; the regression test counts the ppermutes).

    `step_out_fn(u_new, u_old)` optionally produces a per-step output (e.g. a
    residual); its stacked results are returned as the second element (None
    when not provided). Numerics are identical to `steps` iterated calls of
    :func:`stencil_apply` — asserted in tests. `unroll` is forwarded to
    lax.scan (the HLO-inspection tests unroll fully so every exchange is a
    countable op definition).
    """
    n = u.shape[dim]
    if mode != "hdot" or n < 4 * width or steps < 1:
        # two-phase baseline (or degenerate block / empty scan, which keeps
        # the length-0 stacked-outs contract): plain comm->compute scan
        def body(u, _):
            u_new = stencil_apply(u, stencil_fn, axis_name, width, dim,
                                  periodic, mode, subdomains)
            return u_new, step_out_fn(u_new, u) if step_out_fn else None
        return lax.scan(body, u, None, length=steps, unroll=unroll)

    def strips(u, lo_halo, hi_halo):
        lo_src, hi_src = _boundary_srcs(u, lo_halo, hi_halo, width, dim)
        return stencil_fn(lo_src), stencil_fn(hi_src)

    def body(carry, _):
        u, lo_halo, hi_halo = carry
        lo_out, hi_out = strips(u, lo_halo, hi_halo)   # new edge cells
        # The updated block's edge strips ARE lo_out/hi_out — hand them to the
        # ring now so the next step's halos travel while the interior computes.
        lo_next, hi_next = exchange_edges(lo_out, hi_out, axis_name, periodic)
        interior = _interior_chunks(u, stencil_fn, width, dim, subdomains)
        u_new = jnp.concatenate([lo_out, *interior, hi_out], axis=dim)
        out = step_out_fn(u_new, u) if step_out_fn else None
        return (u_new, lo_next, hi_next), out

    lo0, hi0 = exchange_halo(u, axis_name, width, dim, periodic)  # pipeline fill
    if not peel:
        (u, _, _), outs = lax.scan(body, (u, lo0, hi0), None, length=steps,
                                   unroll=unroll)
        return u, outs
    (u, lo_h, hi_h), outs = lax.scan(body, (u, lo0, hi0), None,
                                     length=steps - 1, unroll=unroll)
    # Peeled drain: the last step consumes its halos, launches nothing.
    u_new = stencil_with_halo(u, lo_h, hi_h, stencil_fn, width, dim,
                              subdomains)
    if step_out_fn is not None:
        outs = jax.tree.map(
            lambda s, o: jnp.concatenate([s, o[None]], axis=0), outs,
            step_out_fn(u_new, u))
    return u_new, outs


# --------------------------------------------------------------------------
# 2-D (rows x cols) process decomposition — corner-free two-dim pipelining.
#
# The same interior/boundary over-decomposition, applied on BOTH mesh axes at
# once: a block owns four edge strips (d0-lo/hi spanning the full d1 extent,
# d1-lo/hi covering the remaining interior rows) and a 2-D grid of interior
# chunk tasks cut by the SAME `decompose_grid` scheme the process level uses
# (paper §3.2: one partition function, two levels). Corner ghosts are never
# exchanged: `stencil_fn` must be star-shaped (5-point Jacobi, per-direction
# WENO, ...), so the corner cells of the padded source are dead values.
#
# `stencil_fn(padded)` here consumes a block padded by `width` ghost cells on
# both ends of BOTH dims in `dims` and returns the un-padded update.
# --------------------------------------------------------------------------

def _sl(u: jax.Array, dim: int, a: int, b: int) -> jax.Array:
    return lax.slice_in_dim(u, a, b, axis=dim)


def exchange_halo_2d(u: jax.Array, axis_names: Tuple[str, str], width: int,
                     dims: Tuple[int, int], periodic: bool = False
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Combined edge exchange on both mesh axes (one ppermute pair per axis).
    Returns (lo0, hi0, lo1, hi1); corner ghosts are NOT exchanged."""
    lo0, hi0 = exchange_halo(u, axis_names[0], width, dims[0], periodic)
    lo1, hi1 = exchange_halo(u, axis_names[1], width, dims[1], periodic)
    return lo0, hi0, lo1, hi1


def pad_with_halo_2d(u: jax.Array, halos, width: int, dims: Tuple[int, int]
                     ) -> jax.Array:
    """Assemble the corner-free padded block: halos on the four faces, ZEROS
    in the (2*width)^2 corners (star stencils never read them)."""
    d0, d1 = dims
    lo0, hi0, lo1, hi1 = halos
    shp = list(u.shape)
    shp[d0] = width
    shp[d1] = width
    zc = jnp.zeros(shp, u.dtype)
    mid = jnp.concatenate([lo1, u, hi1], axis=d1)
    top = jnp.concatenate([zc, lo0, zc], axis=d1)
    bot = jnp.concatenate([zc, hi0, zc], axis=d1)
    return jnp.concatenate([top, mid, bot], axis=d0)


def stencil_two_phase_2d(u: jax.Array,
                         stencil_fn: Callable[[jax.Array], jax.Array],
                         axis_names: Tuple[str, str], width: int,
                         dims: Tuple[int, int], periodic: bool = False
                         ) -> jax.Array:
    """comm(both axes); barrier; compute(whole block) — the 2-D baseline."""
    halos = exchange_halo_2d(u, axis_names, width, dims, periodic)
    return stencil_fn(pad_with_halo_2d(u, halos, width, dims))


def _norm_sub2(subdomains) -> Tuple[int, int]:
    if isinstance(subdomains, int):
        return (subdomains, subdomains)
    kr, kc = subdomains
    return (kr, kc)


def _strips_2d(u: jax.Array, lo0, hi0, lo1, hi1,
               stencil_fn: Callable[[jax.Array], jax.Array], width: int,
               dims: Tuple[int, int]) -> Tuple[jax.Array, ...]:
    """The four boundary-strip tasks — the ONLY consumers of the halos.

    Partition of the block: d0 strips own rows [0,w) and [n-w,n) at full d1
    extent; d1 strips own the remaining rows x cols [0,w) / [m-w,m); the
    interior owns the rest. The d1-strip sources span all of u's rows, so
    they consume only the d1 halo — each strip depends on exactly one
    ppermute pair (plus zero corner ghosts, dead for star stencils)."""
    d0, d1 = dims
    w = width
    n, m = u.shape[d0], u.shape[d1]
    shp = list(u.shape)
    shp[d0] = w
    shp[d1] = w
    zc = jnp.zeros(shp, u.dtype)
    rows = jnp.concatenate([lo0, _sl(u, d0, 0, 2 * w)], axis=d0)
    lpad = jnp.concatenate([zc, _sl(lo1, d0, 0, 2 * w)], axis=d0)
    rpad = jnp.concatenate([zc, _sl(hi1, d0, 0, 2 * w)], axis=d0)
    lo0_out = stencil_fn(jnp.concatenate([lpad, rows, rpad], axis=d1))
    rows = jnp.concatenate([_sl(u, d0, n - 2 * w, n), hi0], axis=d0)
    lpad = jnp.concatenate([_sl(lo1, d0, n - 2 * w, n), zc], axis=d0)
    rpad = jnp.concatenate([_sl(hi1, d0, n - 2 * w, n), zc], axis=d0)
    hi0_out = stencil_fn(jnp.concatenate([lpad, rows, rpad], axis=d1))
    lo1_out = stencil_fn(jnp.concatenate([lo1, _sl(u, d1, 0, 2 * w)], axis=d1))
    hi1_out = stencil_fn(jnp.concatenate([_sl(u, d1, m - 2 * w, m), hi1], axis=d1))
    return lo0_out, hi0_out, lo1_out, hi1_out


def _interior_chunks_2d(u: jax.Array,
                        stencil_fn: Callable[[jax.Array], jax.Array],
                        width: int, dims: Tuple[int, int],
                        subdomains: Tuple[int, int]) -> jax.Array:
    """Interior cells [w, n-w) x [w, m-w) as a (kr x kc) grid of independent
    chunk tasks, cut by `decompose_grid` — the process-level partition scheme
    reused at task level. Chunk [a,b)x[c,d) reads only u[a:b+2w, c:d+2w]
    (its subdomain plus ghosts), so chunks are disjoint work the scheduler
    interleaves with both axes' ppermutes."""
    d0, d1 = dims
    w = width
    n, m = u.shape[d0], u.shape[d1]
    ni, mi = n - 2 * w, m - 2 * w
    kr, kc = _norm_sub2(subdomains)
    kr = max(1, min(kr, ni // max(1, 2 * w)))   # keep chunks >= 2*width
    kc = max(1, min(kc, mi // max(1, 2 * w)))
    boxes = interior_boxes((n, m), w, (kr, kc))  # row-major, block coords
    rows = []
    for r in range(kr):
        row = []
        for c in range(kc):
            b = boxes[r * kc + c]
            src = _sl(_sl(u, d0, b.start[0] - w, b.stop[0] + w),
                      d1, b.start[1] - w, b.stop[1] + w)
            row.append(stencil_fn(src))
        rows.append(row[0] if kc == 1 else jnp.concatenate(row, axis=d1))
    return rows[0] if kr == 1 else jnp.concatenate(rows, axis=d0)


def _assemble_2d(strips, interior: jax.Array, dims: Tuple[int, int]
                 ) -> jax.Array:
    lo0_out, hi0_out, lo1_out, hi1_out = strips
    d0, d1 = dims
    mid = jnp.concatenate([lo1_out, interior, hi1_out], axis=d1)
    return jnp.concatenate([lo0_out, mid, hi0_out], axis=d0)


def stencil_with_halo_2d(u: jax.Array, halos,
                         stencil_fn: Callable[[jax.Array], jax.Array],
                         width: int, dims: Tuple[int, int],
                         subdomains=(2, 2)) -> jax.Array:
    """Communication-free half of the 2-D hdot schedule: apply `stencil_fn`
    to a block whose four face halos were ALREADY received."""
    d0, d1 = dims
    if u.shape[d0] < 4 * width or u.shape[d1] < 4 * width:
        return stencil_fn(pad_with_halo_2d(u, halos, width, dims))
    strips = _strips_2d(u, *halos, stencil_fn, width, dims)
    interior = _interior_chunks_2d(u, stencil_fn, width, dims, subdomains)
    return _assemble_2d(strips, interior, dims)


def stencil_hdot_2d(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                    axis_names: Tuple[str, str], width: int,
                    dims: Tuple[int, int], periodic: bool = False,
                    subdomains=(2, 2)) -> jax.Array:
    """2-D interior/boundary over-decomposition: four strip tasks consume the
    two ppermute pairs; the (kr x kc) interior chunk grid depends only on u."""
    d0, d1 = dims
    if u.shape[d0] < 4 * width or u.shape[d1] < 4 * width:
        return stencil_two_phase_2d(u, stencil_fn, axis_names, width, dims,
                                    periodic)
    halos = exchange_halo_2d(u, axis_names, width, dims, periodic)
    return stencil_with_halo_2d(u, halos, stencil_fn, width, dims, subdomains)


def stencil_apply_2d(u: jax.Array,
                     stencil_fn: Callable[[jax.Array], jax.Array],
                     axis_names: Tuple[str, str], width: int,
                     dims: Tuple[int, int], periodic: bool = False,
                     mode: str = "hdot", subdomains=(2, 2)) -> jax.Array:
    if mode == "hdot":
        return stencil_hdot_2d(u, stencil_fn, axis_names, width, dims,
                               periodic, subdomains)
    if mode in ("none", "two_phase"):
        return stencil_two_phase_2d(u, stencil_fn, axis_names, width, dims,
                                    periodic)
    raise ValueError(f"unknown overlap mode {mode!r}")


def halo_scan_2d(u: jax.Array, stencil_fn: Callable[[jax.Array], jax.Array],
                 axis_names: Tuple[str, str], width: int,
                 dims: Tuple[int, int], steps: int, periodic: bool = False,
                 mode: str = "hdot", subdomains=(2, 2),
                 step_out_fn: Optional[Callable[[jax.Array, jax.Array],
                                                jax.Array]] = None,
                 unroll: int = 1, peel: bool = True
                 ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Double-buffered multi-step driver on a (rows x cols) mesh.

    The hdot carry is (block, four face halos). Each step: (1) finish the
    four boundary strips — the only halo consumers; (2) IMMEDIATELY launch
    BOTH axes' ppermute pairs for step k+1 (the new block's d0 edges are
    exactly the d0 strips; its d1 edges are the d1 strips plus the strip
    corners, stitched corner-free); (3) only then chew through the 2-D
    interior chunk grid. Both exchanges are therefore always in flight behind
    the interior compute; the drain step is peeled exactly like
    :func:`halo_scan`. Numerics identical to `steps` iterated
    :func:`stencil_apply_2d` calls — asserted in tests."""
    d0, d1 = dims
    w = width
    n, m = u.shape[d0], u.shape[d1]
    if mode != "hdot" or n < 4 * w or m < 4 * w or steps < 1:
        def body(u, _):
            u_new = stencil_apply_2d(u, stencil_fn, axis_names, w, dims,
                                     periodic, mode, subdomains)
            return u_new, step_out_fn(u_new, u) if step_out_fn else None
        return lax.scan(body, u, None, length=steps, unroll=unroll)

    a0, a1 = axis_names

    def exchange_from_strips(strips):
        lo0_out, hi0_out, lo1_out, hi1_out = strips
        lo0n, hi0n = exchange_edges(lo0_out, hi0_out, a0, periodic)
        # the new block's d1 edges: strip-corner segments stitched around the
        # d1 strips — still built from strips alone, so both ppermute pairs
        # depart before any interior chunk is touched
        lo_e = jnp.concatenate([_sl(lo0_out, d1, 0, w), lo1_out,
                                _sl(hi0_out, d1, 0, w)], axis=d0)
        hi_e = jnp.concatenate([_sl(lo0_out, d1, m - w, m), hi1_out,
                                _sl(hi0_out, d1, m - w, m)], axis=d0)
        lo1n, hi1n = exchange_edges(lo_e, hi_e, a1, periodic)
        return lo0n, hi0n, lo1n, hi1n

    def body(carry, _):
        u, halos = carry
        strips = _strips_2d(u, *halos, stencil_fn, w, dims)
        halos_next = exchange_from_strips(strips)
        interior = _interior_chunks_2d(u, stencil_fn, w, dims, subdomains)
        u_new = _assemble_2d(strips, interior, dims)
        out = step_out_fn(u_new, u) if step_out_fn else None
        return (u_new, halos_next), out

    halos0 = exchange_halo_2d(u, axis_names, w, dims, periodic)  # fill
    if not peel:
        (u, _), outs = lax.scan(body, (u, halos0), None, length=steps,
                                unroll=unroll)
        return u, outs
    (u, halos), outs = lax.scan(body, (u, halos0), None, length=steps - 1,
                                unroll=unroll)
    # peeled drain: consume the carried halos, launch nothing
    u_new = stencil_with_halo_2d(u, halos, stencil_fn, w, dims, subdomains)
    if step_out_fn is not None:
        outs = jax.tree.map(
            lambda s, o: jnp.concatenate([s, o[None]], axis=0), outs,
            step_out_fn(u_new, u))
    return u_new, outs


def multi_dim_stencil(u: jax.Array,
                      per_dim_fn: Callable[[jax.Array, int], jax.Array],
                      decomp: Sequence[Tuple[int, Optional[str]]],
                      width: int, periodic: bool = False,
                      mode: str = "hdot") -> jax.Array:
    """Apply a direction-split stencil along several decomposed dims (the
    CREAMS pattern: euler_LLF_x/y/z are separate per-direction stencils whose
    results sum). `decomp` lists (dim, mesh_axis_or_None); un-sharded dims use
    a local pad."""
    total = None
    for dim, axis_name in decomp:
        fn = partial(per_dim_fn, dim=dim)
        if axis_name is None:
            if periodic:
                padded = jnp.concatenate(
                    [_edge(u, dim, "hi", width), u, _edge(u, dim, "lo", width)], axis=dim)
            else:
                pads = [(0, 0)] * u.ndim
                pads[dim] = (width, width)
                padded = jnp.pad(u, pads)
            out = fn(padded)
        else:
            out = stencil_apply(u, fn, axis_name, width, dim, periodic, mode)
        total = out if total is None else total + out
    return total
