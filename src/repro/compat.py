"""Version-compatibility shims for the pinned container toolchain.

The codebase is written against the modern jax surface (`jax.shard_map`,
``Mesh`` axis types, the ``check_vma=`` kwarg); the container pins jax 0.4.x
where `shard_map` still lives in ``jax.experimental.shard_map`` with the
``check_rep=`` spelling and meshes have no axis types. Importing this module
backfills the gaps in place so every call site can use the modern spelling
unconditionally. On a new-enough jax this is a no-op.

Imported for its side effect by ``repro.core``/``repro.launch.mesh`` (the
modules every mesh-touching entry point goes through). Importing it does NOT
initialize the jax backend — safe before XLA_FLAGS is set.
"""
from __future__ import annotations

import jax
from jax import lax

if not hasattr(lax, "axis_size"):
    def _axis_size(axis_name):
        # psum over a literal 1 short-circuits to the (static) axis size
        return lax.psum(1, axis_name)

    lax.axis_size = _axis_size

def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` returns one dict on modern jax but a
    per-computation LIST of dicts on 0.4.x — normalize to the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, /, *, mesh, in_specs, out_specs, **kwargs):
        if "check_vma" in kwargs:  # renamed from check_rep in newer jax
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)

    jax.shard_map = shard_map
