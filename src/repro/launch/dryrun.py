import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first backend init). Everything below is ordinary code.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on the
production meshes (16x16 single-pod, 2x16x16 multi-pod) and extract the
roofline terms from the compiled artifact.

Per cell:
  runnable pass  — scan-over-layers lowering (the production step). Proves
                   compile + sharding coherence; memory_analysis() is the
                   HBM-fit proof.
  analysis pass  — layers-unrolled lowering at k0 and k1 = k0 + period layers;
                   FLOPs / bytes / collective-wire-bytes extrapolate linearly
                   to the full depth (exact for uniform stacks; XLA counts
                   scan bodies ONCE, measured in the pre-build probe, so the
                   scanned module *cannot* provide per-step FLOPs).

Usage:
  PYTHONPATH=src python src/repro/launch/dryrun.py --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python src/repro/launch/dryrun.py --all            # every cell
  PYTHONPATH=src python src/repro/launch/dryrun.py --report         # aggregate
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, List, Optional

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# Per-arch overrides applied to BOTH passes (recorded in the JSON).
#  - llama3-405b: fp32 AdamW moments alone exceed v5e-256 HBM (405B*8B/256 =
#    12.7 GB/chip); bf16 moments are the documented production choice here.
#    accum_steps=8 bounds remat residual saves + logits to one microbatch
#    (EXPERIMENTS §Dry-run: 106 GB/chip temp without, fits multi-pod with).
ARCH_OVERRIDES: Dict[str, Dict[str, Any]] = {
    "llama3-405b": {"moment_dtype": "bfloat16", "accum_steps": 8},
}


def _build(arch: str, shape_name: str, analysis: bool, num_layers: Optional[int]):
    import jax.numpy as jnp

    from repro.config.registry import get_arch
    from repro.config.shapes import shape_by_name
    from repro.config.base import ParallelConfig
    from repro.launch.steps import build_cell
    from repro.models.model import ModelOptions

    cfg = get_arch(arch)
    shape = shape_by_name(shape_name)
    if num_layers is not None:
        kw = {"num_layers": num_layers}
        cfg = dataclasses.replace(cfg, **kw)
    over = ARCH_OVERRIDES.get(arch, {})
    moment_dtype = jnp.dtype(over.get("moment_dtype", "float32"))

    # Blockwise attention everywhere seq is long enough to matter: the dense
    # path materializes (b, s, s) f32 score tensors that blow the per-chip
    # temp budget at 4k+ (measured: 39.7 GB/chip for internlm2 train_4k dense
    # vs blockwise — see EXPERIMENTS.md §Dry-run). Decode always uses the
    # ring-cache dense path (one query token).
    if analysis:
        # accum kept at 1: FLOPs/collectives per token are accum-invariant and
        # the k0/k1 unrolled extrapolation must not nest a microbatch scan.
        options = ModelOptions(
            attn_impl="blockwise_unrolled" if shape.kind != "decode" else "dense",
            scan_layers=False,
            remat="full" if shape.kind == "train" else "none",
            unroll_chunks=True)
        parallel = ParallelConfig(scan_layers=False, remat=options.remat)
    else:
        options = ModelOptions(
            attn_impl="blockwise" if shape.kind != "decode" else "dense",
            scan_layers=True,
            remat="full" if shape.kind == "train" else "none")
        parallel = ParallelConfig(scan_layers=True, remat=options.remat,
                                  accum_steps=int(over.get("accum_steps", 1)))
    return build_cell(cfg, shape, options, parallel, moment_dtype)


def _layer_period(arch: str) -> int:
    from repro.config.registry import get_arch

    cfg = get_arch(arch)
    if cfg.family == "hybrid":
        return len(cfg.hybrid.pattern)
    return 1


def _extract(compiled, lowered_text: Optional[str] = None) -> Dict[str, Any]:
    from repro.analysis.hlo import count_ops, parse_collectives

    from repro.compat import cost_analysis_dict

    cost = cost_analysis_dict(compiled)
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    coll = parse_collectives(text)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "coll_wire_bytes": coll.total_wire_bytes,
        "coll_wire_bytes_bf16eq": coll.total_wire_bytes_bf16eq,
        "coll_operand_bytes": coll.total_operand_bytes,
        "coll_by_kind": {k: [n, b] for k, (n, b) in coll.by_kind().items()},
        "mem": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "op_counts": {op: count_ops(text, op)
                      for op in ("fusion", "while", "dot", "custom-call",
                                 "transpose", "reshape")},
    }


def _analytic_traffic(cell, cfg, shape, mesh) -> Dict[str, float]:
    """Analytic per-chip HBM traffic (DESIGN §6; memtraffic module)."""
    from repro.analysis.memtraffic import hbm_traffic, sharded_bytes

    ctx = cell.context(mesh)
    chips = mesh.devices.size
    pb = sharded_bytes(cell.arg_specs[0], cell.arg_axes[0], ctx)
    mb = cb = 0.0
    if cell.kind == "train":
        mb = sharded_bytes(cell.arg_specs[1]["m"], cell.arg_axes[1]["m"], ctx) * 2
    elif cell.kind == "decode":
        cb = sharded_bytes(cell.arg_specs[1], cell.arg_axes[1], ctx)
    traffic = hbm_traffic(cfg, shape, chips, pb, mb, cb,
                          remat=(cell.kind == "train"))
    return {"param_bytes_chip": pb, "moment_bytes_chip": mb,
            "cache_bytes_chip": cb, "hbm_traffic_chip": traffic}


def run_cell(arch: str, shape_name: str, multi_pod: bool, analysis: bool,
             out_dir: Path) -> Dict[str, Any]:
    """Lower+compile one cell on one mesh; write JSON; return the record."""
    import jax

    from repro.config.registry import get_arch
    from repro.config.shapes import cell_is_runnable, shape_by_name
    from repro.launch.mesh import make_production_mesh, validate_production_mesh

    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}" + ("__analysis" if analysis else "")
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "analysis": analysis, "tag": tag,
        "jax_devices": len(jax.devices()),
    }
    out_dir.mkdir(parents=True, exist_ok=True)

    cfg = get_arch(arch)
    shape = shape_by_name(shape_name)
    if not cell_is_runnable(cfg.subquadratic, shape):
        rec.update(skipped=True,
                   reason="long_500k requires sub-quadratic attention; "
                          f"{arch} is pure full-attention (DESIGN.md §5)")
        (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        print(f"[dryrun] SKIP {tag}: {rec['reason']}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    validate_production_mesh(mesh, multi_pod=multi_pod)
    try:
        if analysis:
            period = _layer_period(arch)
            k0, k1 = period, 2 * period
            metrics = {}
            for k in (k0, k1):
                cell = _build(arch, shape_name, analysis=True, num_layers=k)
                t0 = time.time()
                lowered = cell.lower(mesh)
                compiled = lowered.compile()
                m = _extract(compiled)
                m["lower_compile_s"] = time.time() - t0
                metrics[k] = m
            L = cfg.num_layers
            extrap: Dict[str, Any] = {}
            for key in ("flops", "bytes_accessed", "coll_wire_bytes",
                        "coll_wire_bytes_bf16eq", "coll_operand_bytes"):
                per = (metrics[k1][key] - metrics[k0][key]) / (k1 - k0)
                extrap[key] = metrics[k1][key] + per * (L - k1)
                extrap[f"{key}_per_layer"] = per
            rec.update(ok=True, k0=k0, k1=k1, layers=L,
                       raw={str(k): metrics[k] for k in metrics},
                       extrapolated=extrap)
        else:
            cell = _build(arch, shape_name, analysis=False, num_layers=None)
            t0 = time.time()
            lowered = cell.lower(mesh)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            rec.update(ok=True, lower_s=t_lower, compile_s=t_compile,
                       **_extract(compiled))
            rec["analytic"] = _analytic_traffic(cell, cfg, shape, mesh)
            print(compiled.memory_analysis())
    except Exception as e:  # recorded, not raised: the report shows red cells
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-4000:])
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    status = "OK" if rec.get("ok") else "FAIL"
    print(f"[dryrun] {status} {tag}")
    return rec


# --------------------------------------------------------------------- report
def load_records(out_dir: Path) -> List[Dict[str, Any]]:
    return [json.loads(p.read_text()) for p in sorted(out_dir.glob("*.json"))]


def report(out_dir: Path) -> str:
    from repro.analysis.roofline import RooflineReport, model_flops_for
    from repro.config.registry import get_arch
    from repro.config.shapes import shape_by_name

    recs = load_records(out_dir)
    runnable = [r for r in recs if not r.get("analysis")]
    analysis = {(r["arch"], r["shape"]): r for r in recs
                if r.get("analysis") and r.get("ok")}

    lines = ["## Dry-run results", "",
             "| arch | shape | mesh | status | compile s | args GB/chip | temp GB/chip |",
             "|---|---|---|---|---|---|---|"]
    for r in sorted(runnable, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP ({r['reason'][:40]}...) | – | – | – |")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"**FAIL** {r.get('error', '')[:60]} | – | – | – |")
            continue
        mem = r["mem"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', 0):.1f} | {mem['argument_bytes']/1e9:.2f} | "
            f"{mem['temp_bytes']/1e9:.2f} |")

    runnable_by_key = {(r["arch"], r["shape"]): r for r in runnable
                       if r.get("ok") and r["mesh"] == "16x16"}
    baseline_dir = out_dir.parent / "dryrun_baseline"
    baselines = {}
    if baseline_dir.exists():
        for rec in (json.loads(p.read_text())
                    for p in baseline_dir.glob("*__analysis.json")):
            if rec.get("ok"):
                baselines[(rec["arch"], rec["shape"])] = rec

    lines += ["", "## Roofline (single-pod 16x16; FLOPs/collectives from the "
              "unrolled analysis lowering, t_mem from the analytic HBM model; "
              "t_coll* = bf16-equivalent wire, see analysis/hlo.py)",
              "",
              "| arch | shape | t_comp ms | t_mem ms | t_coll* ms | dominant | "
              "useful ratio | roofline frac | coll GB vs baseline |",
              "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape_name), r in sorted(analysis.items()):
        if r["mesh"] != "16x16":
            continue
        cfg = get_arch(arch)
        shape = shape_by_name(shape_name)
        tokens = (shape.global_batch if shape.kind == "decode"
                  else shape.global_batch * shape.seq_len)
        mf = model_flops_for(cfg.active_params(), tokens, shape.kind)
        e = r["extrapolated"]
        coll = e.get("coll_wire_bytes_bf16eq", e["coll_wire_bytes"])
        run = runnable_by_key.get((arch, shape_name), {})
        hbm = run.get("analytic", {}).get("hbm_traffic_chip",
                                          e["bytes_accessed"])
        rep = RooflineReport(
            arch=arch, shape=shape_name, mesh=r["mesh"], chips=256,
            hlo_flops=e["flops"], hlo_bytes=hbm,
            coll_bytes=coll, model_flops=mf)
        base = baselines.get((arch, shape_name))
        if base:
            b_coll = base["extrapolated"]["coll_wire_bytes"]
            delta = (f"{b_coll/1e9:.1f} → {e['coll_wire_bytes']/1e9:.1f} "
                     f"({b_coll/max(e['coll_wire_bytes'], 1e-9):.1f}x)")
        else:
            delta = "–"
        lines.append(
            f"| {arch} | {shape_name} | {rep.t_comp*1e3:.2f} | "
            f"{rep.t_mem*1e3:.2f} | {rep.t_coll*1e3:.2f} | {rep.dominant} | "
            f"{rep.useful_flops_ratio:.3f} | {rep.roofline_fraction:.3f} | "
            f"{delta} |")
    return "\n".join(lines)


# ----------------------------------------------------------------------- main
def all_cells() -> List[Dict[str, Any]]:
    from repro.config.registry import list_archs
    from repro.config.shapes import SHAPES

    cells = []
    for arch in list_archs():
        for shape in SHAPES:
            cells.append({"arch": arch, "shape": shape})
    return cells


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--analysis", action="store_true",
                    help="unrolled analysis pass (single-pod roofline terms)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    if args.report:
        print(report(args.out))
        return 0

    todo = (all_cells() if args.all
            else [{"arch": args.arch, "shape": args.shape}])
    rc = 0
    for cell in todo:
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        for multi in meshes:
            if args.analysis and multi:
                continue  # roofline table is single-pod only (brief)
            r = run_cell(cell["arch"], cell["shape"], multi_pod=multi,
                         analysis=args.analysis, out_dir=args.out)
            if not (r.get("ok") or r.get("skipped")):
                rc = 1
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
