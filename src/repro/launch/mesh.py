"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set XLA_FLAGS
before jax initializes, and smoke tests must see exactly 1 CPU device.

Axis roles (DESIGN.md §4):
  pod    — slowest hop (inter-pod). Carries only gradient/MoE collectives.
  data   — intra-pod DP/FSDP axis.
  model  — fastest hop (intra-pod ICI ring): TP/SP/EP axis.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

from repro import compat  # noqa: F401  (backfills jax.shard_map on old jax)


def _auto_kw(n: int) -> dict:
    """axis_types kwarg for jax.make_mesh; {} on jax versions without
    Mesh axis types (all axes are implicitly Auto there)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return {}
    return {"axis_types": (AxisType.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kw(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests/benchmarks (e.g. (8,), ('data',) on 8 host
    devices)."""
    return jax.make_mesh(shape, axes, **_auto_kw(len(axes)))


GRID_AXES = ("rows", "cols")
GRID_AXES_3D = ("planes", "rows", "cols")


def make_grid_mesh(*shape: int, axes: Optional[Tuple[str, ...]] = None) -> Mesh:
    """N-D process mesh for hierarchical domain decomposition (the HDOT
    partition scheme applied on every grid dim at process level; the halo
    machinery reuses the same scheme for its task-level chunk grid).

    ``make_grid_mesh(rows, cols)`` is the 2-D (rows x cols) mesh;
    ``make_grid_mesh(planes, rows, cols)`` the 3-D mesh HPCCG's native grid
    decomposes onto. Size-1 axes keep the full N-D code path alive on lower-
    dimensional layouts — (4, 1) and (1, 4) are the slab topologies expressed
    in the 2-D scheme, (4, 2, 1) a 2-D topology in the 3-D scheme — so
    benchmarks can track topology gaps on equal footing."""
    if axes is None:
        if len(shape) not in (2, 3):
            raise ValueError(f"make_grid_mesh default axes cover 2-D/3-D "
                             f"grids; got shape {shape} — pass axes=")
        axes = GRID_AXES if len(shape) == 2 else GRID_AXES_3D
    if len(axes) != len(shape):
        raise ValueError(f"mesh shape {shape} and axes {axes} disagree")
    return jax.make_mesh(tuple(shape), tuple(axes), **_auto_kw(len(shape)))


def make_single_device_mesh(axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """1-device mesh with the production axis names: lets the full sharded
    code path run on one CPU device (every axis has size 1)."""
    return jax.make_mesh((1,) * len(axes), axes, **_auto_kw(len(axes)))


def describe(mesh: Mesh) -> str:
    return " x ".join(
        f"{n}={s}" for n, s in zip(mesh.axis_names, mesh.devices.shape))


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def validate_production_mesh(mesh: Mesh, *, multi_pod: bool) -> None:
    # a validator that compiles away under `python -O` validates nothing
    want = (2, 16, 16) if multi_pod else (16, 16)
    if tuple(mesh.devices.shape) != want:
        raise ValueError(f"production mesh must be {want}, "
                         f"got {tuple(mesh.devices.shape)}")
    if mesh.devices.size != (512 if multi_pod else 256):
        raise ValueError(f"production mesh has {mesh.devices.size} devices")
