"""Step builders shared by the dry-run, the trainer and the server.

A *cell* is (architecture x input shape). `build_cell` returns the jitted-able
step function plus abstract arg specs, logical axes and donation info — the
dry-run lowers it with ShapeDtypeStructs, the real drivers call it with
arrays. One code path for both is the point: the dry-run proves exactly what
production would run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.elastic import shardings_for
from repro.config.base import ModelConfig, ParallelConfig
from repro.config.shapes import ShapeConfig
from repro.core.overlap import (FsdpLayout, accumulate_grads, fsdp_all_gather,
                                fsdp_layout, fsdp_stream, grad_sync_fsdp)
from repro.models.model import LanguageModel, ModelOptions, build_model, input_specs
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.sharding.rules import ShardingContext, use_sharding

PyTree = Any


def explicit_sync_axes(parallel: ParallelConfig, mesh) -> Tuple[Tuple[str, ...], bool]:
    """(sync_axes, explicit): the DP axes present on `mesh`, and whether the
    explicit shard_map grad-sync schedules are faithful there. The explicit
    schedules treat params as replicated (or DP-sharded) inside shard_map,
    which is only sound when every non-DP mesh axis is trivial — a
    non-trivial TP axis must keep the GSPMD path."""
    if mesh is None:
        return (), False
    sync_axes = tuple(a for a in parallel.dp_axes if a in mesh.axis_names)
    explicit = bool(sync_axes) and all(
        mesh.shape[a] == 1 for a in mesh.axis_names if a not in sync_axes)
    return sync_axes, explicit


@dataclasses.dataclass
class Cell:
    """One lowered unit of work: fn(*args) with full sharding metadata."""

    name: str
    fn: Callable
    arg_specs: Tuple[PyTree, ...]       # ShapeDtypeStruct trees (positional)
    arg_axes: Tuple[PyTree, ...]        # logical-axes trees (same structure)
    donate_argnums: Tuple[int, ...]
    model: LanguageModel
    kind: str                           # train | prefill | decode

    @property
    def rules(self):
        from repro.sharding.rules import rules_for

        return rules_for(self.kind, self.model.cfg.d_model,
                         self.model.cfg.family)

    def context(self, mesh) -> ShardingContext:
        return ShardingContext(mesh, self.rules)

    def in_shardings(self, mesh) -> Tuple[PyTree, ...]:
        ctx = self.context(mesh)
        return tuple(shardings_for(s, a, mesh, ctx)
                     for s, a in zip(self.arg_specs, self.arg_axes))

    def lower(self, mesh, out_shardings=None):
        with use_sharding(mesh, self.rules), mesh:
            jitted = jax.jit(self.fn,
                             in_shardings=self.in_shardings(mesh),
                             out_shardings=out_shardings,
                             donate_argnums=self.donate_argnums)
            return jitted.lower(*self.arg_specs)


# --------------------------------------------------------------------- train
def make_train_step(model: LanguageModel, parallel: ParallelConfig,
                    opt_cfg: Optional[AdamWConfig] = None,
                    warmup_steps: int = 100, total_steps: int = 10_000
                    ) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Gradient reduction over the DP axes is left to GSPMD (params sharded
    FSDP-style); parallel.overlap selects the explicit HDOT bucketed schedule
    when the step runs under shard_map-style manual axes (trainer benches).
    """
    opt_cfg = opt_cfg or AdamWConfig()
    accum = parallel.accum_steps
    # Layer-chunked optimizer update is available (adamw_update chunk_leading)
    # but measured WORSE on the XLA-CPU dry-run (+12 GB: while-loop outputs
    # don't alias donated inputs); the unchunked elementwise update fuses to
    # ~zero temp on the TPU target. Keep unchunked. (EXPERIMENTS §Perf it. 2)
    chunk_leading = 0
    p_axes = model.param_axes()

    def constrain_like_params(grads):
        """Anchor gradient shardings to the parameter placements. Without
        this, GSPMD replicates the (vocab, d_model) embedding/lm_head grads
        (scatter-add / final dot) — measured 8.4 GB/chip f32 buffers for
        llama3-405b (EXPERIMENTS §Perf iteration 1)."""
        from repro.sharding.rules import with_logical

        return jax.tree.map(
            lambda g, ax: with_logical(g, ax), grads, p_axes)

    def loss_and_grad(params, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        return loss, constrain_like_params(grads)

    def step_fn(params, opt_state, batch):
        loss, grads = accumulate_grads(loss_and_grad, params, batch, accum)
        lr = warmup_cosine(opt_state["step"], opt_cfg.lr, warmup_steps,
                           total_steps)
        params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                opt_cfg, lr,
                                                chunk_leading=chunk_leading)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return step_fn


# ------------------------------------------------------------ train (ZeRO-3)
def _require_explicit_mesh(parallel: ParallelConfig, mesh) -> Tuple[str, ...]:
    """sync_axes, or a loud error when the mesh cannot host the explicit
    ZeRO-3 step (a non-trivial TP axis would silently replicate under the
    flat-shard shard_map). Single source for the param_shard precondition."""
    sync_axes, explicit = explicit_sync_axes(parallel, mesh)
    if not explicit:
        raise ValueError(
            "param_shard=True needs the explicit-schedule step: a mesh whose "
            f"non-DP axes are all trivial (got mesh axes "
            f"{dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else None}, "
            f"dp_axes {parallel.dp_axes})")
    return sync_axes


def fsdp_layout_for(model: LanguageModel, parallel: ParallelConfig,
                    mesh) -> Tuple[FsdpLayout, Tuple[str, ...]]:
    """The bucket-wise flat-buffer layout of `model`'s params for ZeRO-3
    sharding over the mesh's DP axes (layer-boundary buckets when
    ``parallel.bucket_order == 'reverse_topo'``; one bucket PER layer when
    ``parallel.fsdp_streaming`` so each gather has a single consuming
    layer)."""
    sync_axes = _require_explicit_mesh(parallel, mesh)
    n_shards = 1
    for a in sync_axes:
        n_shards *= mesh.shape[a]
    order = "layer" if parallel.fsdp_streaming else parallel.bucket_order
    layers = (model.param_layers()
              if order in ("reverse_topo", "layer") else None)
    layout = fsdp_layout(model.abstract_params(), n_shards,
                         parallel.grad_buckets, layers=layers, order=order)
    return layout, sync_axes


def fsdp_init_state(model: LanguageModel, parallel: ParallelConfig, mesh,
                    rng) -> Tuple[Dict[str, jax.Array], PyTree, FsdpLayout]:
    """Materialize the ZeRO-3 trainer state: params and AdamW moments as
    bucket-wise flat buffers placed with ``P(dp_axes)`` shardings —
    per-device parameter/opt residency is 1/n_shards of the replicated
    step's. Returns (params_flat, opt_state, layout).

    Init is SHARDED per bucket: each flat buffer comes out of its own jitted
    init with ``out_shardings=P(dp_axes)``, so the full tree never
    materializes — transient per-device bytes stay within
    ``layout.shard_bytes()`` plus one bucket. Bit-identical to the old
    full-materialize init: every leaf's key derives from its tree path
    (``models.layers.init_leaf``), not from traversal order."""
    import functools

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.models.layers import _leaf_paths, init_leaf

    layout, sync_axes = fsdp_layout_for(model, parallel, mesh)
    sharding = NamedSharding(mesh, P(sync_axes))
    paths = list(_leaf_paths(model.param_specs()).items())
    if len(paths) != layout.num_leaves:  # pragma: no cover - structural guard
        raise ValueError(f"param_specs has {len(paths)} leaves, layout packs "
                         f"{layout.num_leaves}")

    from repro.core.overlap import _pack_group

    def group_init(key, g):
        leaves = [None] * layout.num_leaves
        for i in g.leaf_idx:
            path, spec = paths[i]
            leaves[i] = init_leaf(key, path, spec)
        return _pack_group(leaves, g)

    def group_zeros(g):
        return jnp.zeros((g.padded,), jnp.float32)

    flat, m, v = {}, {}, {}
    with mesh:
        for g in layout.groups:
            flat[g.key] = jax.jit(functools.partial(group_init, g=g),
                                  out_shardings=sharding)(rng)
            zeros = jax.jit(functools.partial(group_zeros, g),
                            out_shardings=sharding)
            m[g.key], v[g.key] = zeros(), zeros()
    opt = {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}
    return flat, opt, layout


def make_fsdp_train_step(model: LanguageModel, parallel: ParallelConfig, mesh,
                         opt_cfg: Optional[AdamWConfig] = None,
                         warmup_steps: int = 100, total_steps: int = 10_000,
                         layout: Optional[FsdpLayout] = None) -> Callable:
    """(params_flat, opt_state, batch) -> (params_flat, opt_state, metrics):
    the FSDP (ZeRO-3) composition of the explicit HDOT grad-sync schedule.

    Inside shard_map over the DP axes: bucket-wise all-gather of the flat
    parameter shards in FORWARD order, loss/backward on the gathered params,
    then a bucket-wise reduce-scatter EMITTED reverse-topologically (the
    last-backward bucket's collective first, free to depart while earlier
    layers' backward computes). The AdamW update then runs OUTSIDE shard_map
    directly on the flat shards — elementwise math GSPMD keeps partitioned,
    so optimizer state never materializes unsharded.

    With ``parallel.fsdp_streaming`` the top-of-step gather-all is replaced
    by the streaming schedule: per-layer buckets are all-gathered inside
    each consuming layer's remat region (``train_loss_streamed``), freed
    after that layer's forward, and REGATHERED in reverse order by the
    backward — whose AD transpose emits the per-bucket reduce-scatters
    last-backward-first automatically. Peak live params drop from the full
    tree to shard + a ``fsdp_working_set``-bucket working set; losses,
    params and moments stay bit-identical to the gather-all step."""
    opt_cfg = opt_cfg or AdamWConfig()
    accum = parallel.accum_steps
    if layout is None:
        layout, sync_axes = fsdp_layout_for(model, parallel, mesh)
    else:
        sync_axes = _require_explicit_mesh(parallel, mesh)
    n_shards = layout.n_shards

    def loss_and_grad(params, batch):
        return jax.value_and_grad(model.train_loss)(params, batch)

    if parallel.fsdp_streaming:
        stream = fsdp_stream(layout, model.param_layers(), sync_axes)

        def streamed_loss_and_grad(pflat, batch):
            return jax.value_and_grad(model.train_loss_streamed)(
                pflat, batch, stream)

        def local(pflat, b):
            from repro.sharding.rules import no_sharding

            # manual region: logical sharding constraints must be inert
            with no_sharding():
                # gathers are emitted point-of-use inside the loss; AD
                # returns grads already reduce-scattered per bucket
                loss, gflat = accumulate_grads(streamed_loss_and_grad,
                                               pflat, b, accum)
            gflat = {k: v / n_shards for k, v in gflat.items()}
            return jax.lax.pmean(loss, sync_axes), gflat
    else:
        def local(pflat, b):
            from repro.sharding.rules import no_sharding

            # manual region: logical sharding constraints must be inert
            with no_sharding():
                params = fsdp_all_gather(pflat, layout, sync_axes)
                loss, g = accumulate_grads(loss_and_grad, params, b, accum)
                gflat = grad_sync_fsdp(g, layout, sync_axes)
            # psum_scatter of per-shard mean-grads -> global mean over shards
            gflat = {k: v / n_shards for k, v in gflat.items()}
            return jax.lax.pmean(loss, sync_axes), gflat

    def grads_fn(pflat, batch):
        from jax.sharding import PartitionSpec as P

        flat_specs = {k: P(sync_axes) for k in layout.keys}
        batch_specs = jax.tree.map(
            lambda x: P(sync_axes, *([None] * (x.ndim - 1))), batch)
        return jax.shard_map(
            local, mesh=mesh, in_specs=(flat_specs, batch_specs),
            out_specs=(P(), flat_specs), check_vma=False)(pflat, batch)

    def step_fn(pflat, opt_state, batch):
        loss, gflat = grads_fn(pflat, batch)
        lr = warmup_cosine(opt_state["step"], opt_cfg.lr, warmup_steps,
                           total_steps)
        pflat, opt_state, gnorm = adamw_update(gflat, opt_state, pflat,
                                               opt_cfg, lr)
        return pflat, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return step_fn


# --------------------------------------------------------------------- serve
def make_prefill_step(model: LanguageModel) -> Callable:
    def prefill_fn(params, batch):
        return model.prefill(params, batch)

    return prefill_fn


def make_decode_step(model: LanguageModel) -> Callable:
    def decode_fn(params, caches, token, pos):
        logits, new_caches = model.decode_step(params, token, caches, pos)
        return logits, new_caches

    return decode_fn


# ---------------------------------------------------------------- cell build
def opt_state_specs(model: LanguageModel, moment_dtype=jnp.float32
                    ) -> Tuple[PyTree, PyTree]:
    """(abstract opt state, logical axes) matching adamw_init(params)."""
    p_abs = model.abstract_params()
    p_axes = model.param_axes()
    mom = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, moment_dtype),
                       p_abs)
    specs = {"m": mom, "v": mom,
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    axes = {"m": p_axes, "v": p_axes, "step": ()}
    return specs, axes


def build_cell(cfg: ModelConfig, shape: ShapeConfig,
               options: Optional[ModelOptions] = None,
               parallel: Optional[ParallelConfig] = None,
               moment_dtype=jnp.float32) -> Cell:
    parallel = parallel or ParallelConfig()
    options = options or ModelOptions(
        attn_impl="blockwise" if shape.seq_len > 8192 else "dense",
        scan_layers=parallel.scan_layers, remat=parallel.remat,
        moe_a2a_chunks=parallel.moe_a2a_chunks)
    model = build_model(cfg, options)
    io = input_specs(cfg, shape, options)
    batch_specs, batch_axes = io["specs"], io["axes"]
    p_abs = model.abstract_params()
    p_axes = model.param_axes()

    if shape.kind == "train":
        fn = make_train_step(model, parallel)
        o_abs, o_axes = opt_state_specs(model, moment_dtype)
        return Cell(
            name=f"{cfg.name}:{shape.name}", fn=fn,
            arg_specs=(p_abs, o_abs, batch_specs),
            arg_axes=(p_axes, o_axes, batch_axes),
            donate_argnums=(0, 1), model=model, kind="train")

    if shape.kind == "prefill":
        fn = make_prefill_step(model)
        return Cell(
            name=f"{cfg.name}:{shape.name}", fn=fn,
            arg_specs=(p_abs, batch_specs),
            arg_axes=(p_axes, batch_axes),
            donate_argnums=(), model=model, kind="prefill")

    # decode: batch_specs = {'token', 'caches', 'pos'}
    fn = make_decode_step(model)
    return Cell(
        name=f"{cfg.name}:{shape.name}", fn=fn,
        arg_specs=(p_abs, batch_specs["caches"], batch_specs["token"],
                   batch_specs["pos"]),
        arg_axes=(p_axes, batch_axes["caches"], batch_axes["token"],
                  batch_axes["pos"]),
        donate_argnums=(1,), model=model, kind="decode")
