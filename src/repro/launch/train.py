"""Training launcher: ``--arch <id>`` + mesh flags -> Trainer loop.

On this CPU container it runs reduced configs end-to-end (the ~100M example
uses it); on a real pod slice the same driver runs the full config — the mesh
flags select make_production_mesh and the step is GSPMD-sharded per
sharding.rules.

Fault tolerance: --restarts N wraps the loop in the FaultTolerantRunner so an
injected/real failure resumes from the latest checkpoint (exact data order).
"""
from __future__ import annotations

import argparse
from typing import Optional

from repro.config.base import ParallelConfig, RunConfig, TrainConfig
from repro.config.registry import get_arch


def build_run(arch: str, *, reduced: bool = True, steps: int = 50,
              global_batch: int = 8, seq_len: int = 128,
              checkpoint_dir: str = "/tmp/repro_ckpt",
              overlap: str = "hdot", accum_steps: int = 1) -> RunConfig:
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    # namespace per arch: a shared dir would otherwise restore a FOREIGN
    # checkpoint into a mismatched param tree (caught by a KeyError in
    # restore, but the right behavior is isolation)
    checkpoint_dir = f"{checkpoint_dir.rstrip('/')}/{cfg.name}"
    return RunConfig(
        model=cfg,
        parallel=ParallelConfig(overlap=overlap, accum_steps=accum_steps,
                                remat="none" if reduced else "full"),
        train=TrainConfig(global_batch=global_batch, seq_len=seq_len,
                          total_steps=steps, warmup_steps=max(1, steps // 10),
                          checkpoint_every=max(1, steps // 5),
                          checkpoint_dir=checkpoint_dir),
    )


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full (non-reduced) config — pod-scale hardware only")
    ap.add_argument("--mesh", choices=["none", "single-device", "production",
                                       "production-multipod"], default="none")
    ap.add_argument("--overlap", choices=["hdot", "two_phase"], default="hdot")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--restarts", type=int, default=0,
                    help="fault-tolerant restarts budget (runtime.ft)")
    args = ap.parse_args(argv)

    from repro.launch.mesh import (make_production_mesh,
                                   make_single_device_mesh)
    from repro.runtime.trainer import Trainer

    mesh = None
    if args.mesh == "single-device":
        mesh = make_single_device_mesh()
    elif args.mesh == "production":
        mesh = make_production_mesh()
    elif args.mesh == "production-multipod":
        mesh = make_production_mesh(multi_pod=True)

    run = build_run(args.arch, reduced=not args.full, steps=args.steps,
                    global_batch=args.global_batch, seq_len=args.seq_len,
                    checkpoint_dir=args.checkpoint_dir, overlap=args.overlap,
                    accum_steps=args.accum_steps)
    trainer = Trainer(run, mesh=mesh)

    if args.restarts:
        from repro.runtime.ft import FaultTolerantRunner

        runner = FaultTolerantRunner(lambda: Trainer(run, mesh=mesh),
                                     max_restarts=args.restarts)
        trainer = runner.run(args.steps)
        print(f"[train] reached step {trainer.step} "
              f"({runner.restarts} restarts used)")
    else:
        if args.resume:
            trainer.restore_if_available()
        result = trainer.train(args.steps)
        print(f"[train] {result}")
    losses = [m["loss"] for m in trainer.metrics_log] if trainer.metrics_log else []
    if losses:
        print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
