"""Serving launcher: batched prefill+decode over a reduced config.

``--scheduler continuous`` (default) runs true continuous batching
(token-granular slot re-admission, runtime/server.py:run_continuous);
``--scheduler wave`` runs the static wave baseline. Demonstrates the
serve_step lowered by the decode_* dry-run shapes actually running (reduced
sizes, CPU). Production-scale serving lowers the identical step via
launch.steps.build_cell — the dry-run proves those shardings.
"""
from __future__ import annotations

import argparse
from typing import Optional

import jax
import numpy as np


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--scheduler", choices=("continuous", "wave"),
                    default="continuous",
                    help="continuous = token-granular slot re-admission; "
                         "wave = static batches decoded to the slowest member")
    args = ap.parse_args(argv)

    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model
    from repro.runtime.server import BatchServer, Request

    cfg = get_arch(args.arch).reduced()
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, params, slots=args.slots, max_len=256)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, args.prompt_len).tolist()
        server.submit(Request(prompt=prompt, max_new_tokens=args.max_new))
    if args.scheduler == "continuous":
        served = server.run_continuous()
    else:
        served = server.run_all()
    for i, r in enumerate(served):
        print(f"[serve] req{i:02d} -> {len(r.output)} tokens: {r.output[:8]}...")
    how = (f"{server.stats['decode_steps']} decode steps"
           if args.scheduler == "continuous"
           else f"{server.stats['waves']} waves")
    print(f"[serve] served {len(served)} requests ({args.scheduler}: {how})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
