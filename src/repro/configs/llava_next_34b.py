"""llava-next-34b [vlm] — anyres tiling; transformer BACKBONE only, the vision
frontend is a STUB (input_specs provides precomputed patch embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.config.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    # anyres base grid: 24x24 patches = 576 precomputed patch embeddings
    num_vision_patches=576,
)
