"""qwen3-moe-30b-a3b [moe] — 128 fine-grained experts top-8, qk-norm GQA.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.config.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,  # per-expert intermediate (fine-grained experts)
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
)
