"""recurrentgemma-2b [hybrid] — RG-LRU + local attn, 1:2 (pattern rglru,rglru,attn).
MQA (kv=1). [arXiv:2402.19427; hf]"""
from repro.config.base import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    tie_embeddings=True,
    hybrid=HybridConfig(pattern=("rglru", "rglru", "attn"), lru_width=2560, local_window=2048),
)
