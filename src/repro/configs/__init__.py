"""One module per assigned architecture (exact public-literature configs).

Selectable via ``--arch <id>`` through :mod:`repro.config.registry`.
"""
