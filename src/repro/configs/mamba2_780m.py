"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.config.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=1,       # unused (attn-free); SSD heads come from SSMConfig
    num_kv_heads=1,
    d_ff=0,            # no FFN block: mamba2 block is the whole layer
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_kernel=4, chunk_size=256),
)
