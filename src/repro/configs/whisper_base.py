"""whisper-base [audio] — enc-dec; conv frontend is a STUB (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.config.base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    num_layers=6,          # decoder layers
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    tie_embeddings=True,
    encdec=EncDecConfig(enc_layers=6, enc_seq=1500),
)
