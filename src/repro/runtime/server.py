"""Batched serving runtime: prefill + iterative decode over slot-batched
caches, under two schedulers sharing one cache layout.

``run_wave`` is the static policy: pad up to `slots` waiting prompts into one
prefill, decode with a single shared position until every slot finishes.

``run_continuous`` is true continuous batching: the moment a slot frees (EOS
or max_new_tokens) the next queued request is admitted into it — an
exact-width batch-1 prefill plus slot-level cache surgery
(`dynamic_update_slice` of that slot's rows into the live caches), while the
decode step itself stays one static-shape jitted program over all `slots`
with a per-slot position vector. Because admission prefills at the exact
prompt width (no padding enters attention) and replaces the slot's cache rows
wholesale, every request's outputs are bit-identical to serving it alone on a
1-slot server (tests/test_server.py locks this).

The decode step can be swapped out (``decode_step_fn``) for the TP-sharded
cell in models/decode_tp, which routes every projection/FFN matmul through
the HDOT collective matmuls.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.model import LanguageModel

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    output: Optional[List[int]] = None
    # set by the server: submission id (also the non-greedy sampling stream
    # id, so outputs are independent of arrival interleaving) and the
    # monotonic completion timestamp (serving-latency benchmarks)
    rid: Optional[int] = None
    finish: Optional[float] = None


# ------------------------------------------------------- slot-cache surgery
def _is_pos_path(path) -> bool:
    last = path[-1]
    return getattr(last, "key", None) == "pos"


def make_slot_caches(model: LanguageModel, slots: int, max_len: int) -> PyTree:
    """Decode caches for the continuous scheduler: the shared per-batch
    ``pos`` ring index (w,) becomes per-slot (slots, w), initialized to -1
    (= empty; `init_caches` zero-fill would claim position 0 as attended)."""
    caches = model.init_caches(slots, max_len)

    def fix(path, leaf):
        if _is_pos_path(path):
            return jnp.full(leaf.shape[:-1] + (slots, leaf.shape[-1]), -1,
                            jnp.int32)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, caches)


def _mark_prefill_tail(caches: PyTree, plen: int) -> PyTree:
    """A prompt shorter than the ring leaves the ``pos`` tail at its init
    value (0 = "position 0, attended") — mark everything past the prompt as
    empty. No-op for prompts that filled/wrapped the ring (the s >= w prefill
    path already -1-fills)."""

    def fix(path, leaf):
        if _is_pos_path(path):
            w = leaf.shape[-1]
            return jnp.where(jnp.arange(w) < plen, leaf, -1)
        return leaf

    return jax.tree_util.tree_map_with_path(fix, caches)


def _scatter_slot(dst: PyTree, src: PyTree, slot: jax.Array, slots: int
                  ) -> PyTree:
    """Write a batch-1 prefill cache into row `slot` of the server caches.
    Per-slot ``pos`` leaves gain the slot axis at -2; every other leaf
    already carries the slot batch axis and is replaced row-wise."""

    def one(d, s):
        s = s.astype(d.dtype)
        if d.ndim == s.ndim + 1:
            ax = d.ndim - 2
            return lax.dynamic_update_slice_in_dim(
                d, jnp.expand_dims(s, ax), slot, ax)
        ax = next(i for i, (ds_, ss_) in enumerate(zip(d.shape, s.shape))
                  if ss_ == 1 and ds_ == slots)
        return lax.dynamic_update_slice_in_dim(d, s, slot, ax)

    return jax.tree.map(one, dst, src)


class BatchServer:
    def __init__(self, model: LanguageModel, params: PyTree, slots: int = 8,
                 max_len: int = 1024, greedy: bool = True, seed: int = 0,
                 decode_step_fn: Optional[Callable] = None):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {max_len}")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self._base_key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.stats: Dict[str, int] = {"decode_steps": 0, "prefills": 0,
                                      "waves": 0, "admitted": 0}
        self._next_rid = 0
        # cache capacity must cover prompt + generation, else generated
        # tokens evict the prompt from the ring (model.prefill docstring)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=self.max_len))
        self._decode = jax.jit(model.decode_step)
        self._decode_step_fn = decode_step_fn
        self._cont: Optional[Dict[str, Any]] = None
        self._admit_fns: Dict[int, Callable] = {}

    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt: nothing to prefill")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {req.max_new_tokens}")
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"prompt ({len(req.prompt)} tokens) + max_new_tokens "
                f"({req.max_new_tokens}) = {need} exceeds the server's "
                f"cache capacity max_len={self.max_len}; generated tokens "
                f"would evict the prompt from the ring cache")
        if req.rid is None:
            req.rid = self._next_rid
            self._next_rid += 1
        self.queue.append(req)

    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        lens = [len(r.prompt) for r in reqs]
        width = max(lens)
        toks = np.zeros((len(reqs), width), np.int32)
        for i, r in enumerate(reqs):
            toks[i, width - len(r.prompt):] = r.prompt  # left-pad
        return toks

    # ------------------------------------------------------- wave scheduler
    def run_wave(self) -> List[Request]:
        """Serve up to `slots` queued requests to completion."""
        if not self.queue:
            return []
        reqs, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        toks = self._pad_prompts(reqs)
        b, plen = toks.shape
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        self.stats["prefills"] += 1
        self.stats["waves"] += 1
        max_new = max(r.max_new_tokens for r in reqs)
        outputs = [[] for _ in reqs]
        done = np.zeros(b, bool)
        token = self._sample(logits)
        pos = plen
        for _ in range(max_new):
            for i, r in enumerate(reqs):
                t = int(token[i, 0])
                if not done[i]:
                    outputs[i].append(t)
                    if ((r.eos_id is not None and t == r.eos_id)
                            or len(outputs[i]) >= r.max_new_tokens):
                        done[i] = True
                        r.finish = time.monotonic()
            if done.all():
                break
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.asarray(pos, jnp.int32))
            self.stats["decode_steps"] += 1
            token = self._sample(logits)
            pos += 1
        for r, out in zip(reqs, outputs):
            r.output = out
        return reqs

    def run_all(self) -> List[Request]:
        served: List[Request] = []
        while self.queue:
            served.extend(self.run_wave())
        return served

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits[:, -1, :])[:, None].astype(jnp.int32)

    # ------------------------------------------------- continuous scheduler
    def run_continuous(self, poll: Optional[Callable[[], bool]] = None
                       ) -> List[Request]:
        """Token-granular continuous batching: serve the queue to completion,
        admitting a queued request into a slot the same step it frees.

        `poll`, if given, is called once per scheduler iteration; it may
        submit new requests and returns True while more arrivals may still
        come (the benchmark's Poisson trace) — the loop then idles instead of
        returning when the queue drains.
        """
        if self.model.cfg.family in ("vlm", "encdec"):
            raise NotImplementedError(
                "continuous batching admits via token-only prefill; family "
                f"{self.model.cfg.family!r} needs frontend inputs per request")
        self._ensure_continuous_state()
        st = self._cont
        served: List[Request] = []
        while True:
            more = bool(poll()) if poll is not None else False
            # token-granular admission: fill every free slot from the queue
            for s in range(self.slots):
                if st["req"][s] is not None:
                    continue
                while self.queue:
                    req = self.queue.pop(0)
                    tok = self._admit(req, s)
                    req.output = [tok]
                    if self._finished(req, tok):
                        # EOS or max_new_tokens=1 on the first sampled token:
                        # the slot is still free — admit the next request now
                        req.finish = time.monotonic()
                        served.append(req)
                        continue
                    st["req"][s] = req
                    st["tok"][s] = tok
                    st["pos"][s] = len(req.prompt)
                    break
            active = [i for i in range(self.slots) if st["req"][i] is not None]
            if not active:
                if self.queue:
                    continue
                if more:
                    time.sleep(5e-4)
                    continue
                break
            # one static-shape decode step over ALL slots; idle rows carry
            # stale token/pos and only ever write their own cache rows, which
            # admission replaces wholesale
            logits, st["caches"] = self._decode_cont(
                self.params, jnp.asarray(st["tok"][:, None]), st["caches"],
                jnp.asarray(st["pos"]))
            self.stats["decode_steps"] += 1
            rows = np.asarray(logits)[:, -1, :]
            st["pos"] += 1
            for i in active:
                r = st["req"][i]
                t = self._sample_row(rows[i], r)
                r.output.append(t)
                st["tok"][i] = t
                if self._finished(r, t):
                    r.finish = time.monotonic()
                    served.append(r)
                    st["req"][i] = None  # freed: next iteration admits here
        return served

    def _finished(self, req: Request, tok: int) -> bool:
        return ((req.eos_id is not None and tok == req.eos_id)
                or len(req.output) >= req.max_new_tokens)

    def _sample_row(self, row: np.ndarray, req: Request) -> int:
        """Sample one token for one slot. Non-greedy keys are derived from
        (request id, #generated) — NOT from a shared split sequence — so the
        sampled stream is identical however arrivals interleave."""
        if self.greedy:
            return int(np.argmax(row))
        n = 0 if req.output is None else len(req.output)
        k = jax.random.fold_in(
            jax.random.fold_in(self._base_key, req.rid), n)
        return int(jax.random.categorical(k, jnp.asarray(row)))

    def _ensure_continuous_state(self) -> None:
        if self._cont is not None:
            return
        decode = self._decode_step_fn or self.model.decode_step
        self._decode_cont = jax.jit(decode, donate_argnums=(2,))
        self._cont = {
            "caches": make_slot_caches(self.model, self.slots, self.max_len),
            "req": [None] * self.slots,
            "tok": np.zeros(self.slots, np.int32),
            "pos": np.zeros(self.slots, np.int32),
        }

    def _admit(self, req: Request, slot: int) -> int:
        """Prefill `req` at its exact prompt width (batch 1, no padding — the
        outputs stay bit-identical to a solo server) and scatter the prefill
        cache into the freed slot's rows; returns the first sampled token."""
        plen = len(req.prompt)
        fn = self._admit_fns.get(plen)
        if fn is None:
            fn = self._build_admit(plen)
            self._admit_fns[plen] = fn
        toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
        logits, self._cont["caches"] = fn(
            self.params, toks, self._cont["caches"],
            jnp.asarray(slot, jnp.int32))
        self.stats["prefills"] += 1
        self.stats["admitted"] += 1
        row = np.asarray(logits)[0, -1]
        return self._sample_row(row, req)

    def _build_admit(self, plen: int) -> Callable:
        """One jitted admission program per distinct prompt length: exact-
        width prefill + pos-tail fix + slot cache surgery, caches donated."""
        model, slots, max_len = self.model, self.slots, self.max_len

        def admit(params, tokens, caches, slot):
            logits, pc = model.prefill(params, {"tokens": tokens},
                                       max_len=max_len)
            pc = _mark_prefill_tail(pc, plen)
            return logits, _scatter_slot(caches, pc, slot, slots)

        return jax.jit(admit, donate_argnums=(2,))
