"""Batched serving runtime: prefill + iterative decode over slot-batched
caches (wave-scheduled continuous batching).

Requests are padded into fixed `slots`; a wave = one prefill of all waiting
prompts + a decode loop until every slot finishes (EOS or max_new_tokens).
Slot-level cache surgery (true token-granular continuous batching) drops into
the same cache layout — the wave scheduler is the simplest policy that keeps
the decode step shape static for XLA.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import LanguageModel

PyTree = Any


@dataclasses.dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    output: Optional[List[int]] = None


class BatchServer:
    def __init__(self, model: LanguageModel, params: PyTree, slots: int = 8,
                 max_len: int = 1024, greedy: bool = True, seed: int = 0):
        if slots < 1:
            raise ValueError(f"slots must be >= 1, got {slots}")
        if max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {max_len}")
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.greedy = greedy
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        # cache capacity must cover prompt + generation, else generated
        # tokens evict the prompt from the ring (model.prefill docstring)
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, max_len=self.max_len))
        self._decode = jax.jit(model.decode_step)

    def submit(self, req: Request) -> None:
        if not req.prompt:
            raise ValueError("empty prompt: nothing to prefill")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {req.max_new_tokens}")
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"prompt ({len(req.prompt)} tokens) + max_new_tokens "
                f"({req.max_new_tokens}) = {need} exceeds the server's "
                f"cache capacity max_len={self.max_len}; generated tokens "
                f"would evict the prompt from the ring cache")
        self.queue.append(req)

    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        lens = [len(r.prompt) for r in reqs]
        width = max(lens)
        toks = np.zeros((len(reqs), width), np.int32)
        for i, r in enumerate(reqs):
            toks[i, width - len(r.prompt):] = r.prompt  # left-pad
        return toks

    def run_wave(self) -> List[Request]:
        """Serve up to `slots` queued requests to completion."""
        if not self.queue:
            return []
        reqs, self.queue = self.queue[:self.slots], self.queue[self.slots:]
        toks = self._pad_prompts(reqs)
        b, plen = toks.shape
        logits, caches = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        max_new = max(r.max_new_tokens for r in reqs)
        outputs = [[] for _ in reqs]
        done = np.zeros(b, bool)
        token = self._sample(logits)
        pos = plen
        for _ in range(max_new):
            for i, r in enumerate(reqs):
                t = int(token[i, 0])
                if not done[i]:
                    outputs[i].append(t)
                    if ((r.eos_id is not None and t == r.eos_id)
                            or len(outputs[i]) >= r.max_new_tokens):
                        done[i] = True
            if done.all():
                break
            logits, caches = self._decode(self.params, token, caches,
                                          jnp.asarray(pos, jnp.int32))
            token = self._sample(logits)
            pos += 1
        for r, out in zip(reqs, outputs):
            r.output = out
        return reqs

    def run_all(self) -> List[Request]:
        served: List[Request] = []
        while self.queue:
            served.extend(self.run_wave())
        return served

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.greedy:
            return jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        self.key, k = jax.random.split(self.key)
        return jax.random.categorical(k, logits[:, -1, :])[:, None].astype(jnp.int32)
