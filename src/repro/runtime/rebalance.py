"""Measured-cost dynamic re-partitioning on the HDOT schedule.

The paper argues over-decomposition absorbs load imbalance; this module closes
the loop and makes the cut *adaptive*: per-chunk wall-clock is recorded
outside jit into a :class:`repro.core.cost.CostModel`, every K steps the
interior chunk grid is re-cut from the measured per-cell rates
(:func:`repro.core.domain.part_extents`), and the solver recompiles ONLY when
the cut actually changes (the jitted-solver caches key on the canonical cut).
The communication schedule is untouched: onion faces depend on the halo width
alone, never on where the interior is cut, so a weighted re-cut lowers to the
exact same ppermute program shape (see the ``heat2d_weighted`` lint target).

Two drivers live here:

* :func:`heat2d_solve_rebalanced` — in-process segment loop around
  :func:`repro.core.stencil.heat2d_solve`; per-chunk costs come from an
  injectable ``chunk_cost_fn`` (real per-chunk timers don't exist inside a
  compiled program — a production harness feeds profiler data here, tests
  feed synthetic skew).
* :func:`straggler_drill` — a LIVE multi-process drill: numpy-only Jacobi
  band workers behind pipes, one optionally slowed, the coordinator re-cuts
  the band decomposition from measured per-worker rates and (on worker
  death) reroutes bands via :func:`repro.runtime.ft.reassign_host_shards`.

repro imports stay inside functions: the drill's spawned workers re-import
this module and must not pay for jax (``repro.core.__init__`` pulls the
compat shims, which import jax).
"""
from __future__ import annotations

import itertools
import math
import multiprocessing as mp
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


def _extents_to_ranges(extents: Sequence[int]) -> List[Tuple[int, int]]:
    """Chunk extents -> half-open (start, stop) ranges along one dim."""
    out, a = [], 0
    for e in extents:
        out.append((a, a + e))
        a += e
    return out


# ================================================== in-process re-cut driver
def heat2d_solve_rebalanced(u0, mesh, mesh_axes, iters: int,
                            mode: str = "hdot", subdomains=4,
                            rebalance_every: int = 8,
                            cost_model=None,
                            chunk_cost_fn: Optional[Callable] = None):
    """heat2d_solve with a measured-cost re-cut loop.

    Runs `iters` sweeps in segments of `rebalance_every`; after each segment
    the per-chunk costs are folded into the cost model's EMAs, marginalized
    into per-dim per-cell profiles (:meth:`CostModel.weights_along`) and the
    interior chunk grid is re-cut. An unchanged cut (and any cut that lands
    back on uniform) hits the same compiled program — recompiles happen only
    when the partition actually moves.

    `chunk_cost_fn(chunk_index, chunk_shape) -> seconds` supplies per-chunk
    measurements (grid-index keyed, local-interior chunk shapes). Without it
    the cut stays static: whole-segment wall clock has no per-chunk
    resolution, so there is nothing to re-cut on.

    `rebalance_every=0` disables re-cutting (one segment, static uniform cut
    — bit-identical to plain :func:`heat2d_solve`).

    Returns ``(u, residuals, info)`` with ``info["cut_history"]`` the list of
    canonical cuts used (length 1 + number of recompiles).
    """
    from repro.core.cost import CostModel
    from repro.core.domain import part_extents
    from repro.core.halo import _norm_subn
    from repro.core.stencil import heat2d_solve, normalize_mesh_axes

    if rebalance_every < 0:
        raise ValueError(
            f"rebalance_every must be >= 0, got {rebalance_every}")
    axes = normalize_mesh_axes(mesh_axes, "heat2d_solve_rebalanced", (1, 2))
    cost = cost_model if cost_model is not None else CostModel()
    subs = _norm_subn(subdomains, len(axes))
    width = 1

    inner, grid = [], []
    for d, name in enumerate(axes):
        n_local = u0.shape[d] // mesh.shape[name]
        e = max(0, n_local - 2 * width)
        inner.append(e)
        grid.append(max(1, min(subs[d], e // (2 * width))))
    cuts = tuple(part_extents(e, k, None) for e, k in zip(inner, grid))

    u, residuals = u0, []
    cut_history = [cuts]
    seg = rebalance_every if rebalance_every > 0 else iters
    done = 0
    while done < iters:
        n = min(seg, iters - done)
        u, r = heat2d_solve(u, mesh, axes, n, mode, subdomains,
                            chunk_weights=cuts)
        residuals.append(np.atleast_1d(np.asarray(r)))
        done += n
        if done >= iters or rebalance_every <= 0:
            break

        if chunk_cost_fn is None:
            # whole-segment wall clock has no per-chunk resolution: there is
            # no signal to re-cut on, so the partition stays where it is
            continue
        ranges = [_extents_to_ranges(c) for c in cuts]
        for idx in itertools.product(*[range(len(rg)) for rg in ranges]):
            shape = tuple(rg[i][1] - rg[i][0] for rg, i in zip(ranges, idx))
            cells = max(1, math.prod(shape))
            cost.record(idx, chunk_cost_fn(idx, shape), cells=cells)
        wts = cost.weights_along(ranges)
        new_cuts = tuple(part_extents(e, len(c), w)
                         for e, c, w in zip(inner, cuts, wts))
        if new_cuts != cuts:
            cuts = new_cuts
            cut_history.append(cuts)

    info = {"cut_history": cut_history, "recompiles": len(cut_history) - 1,
            "cost_model": cost}
    return u, np.concatenate(residuals), info


# ======================================================= live straggler drill
def _drill_init(rows: int, cols: int) -> np.ndarray:
    """Hot square blob, Dirichlet-0 edges (numpy twin of heat2d_init)."""
    u = np.zeros((rows, cols), np.float32)
    w = max(1, rows // 8)
    u[rows // 2 - w:rows // 2 + w, cols // 2 - w:cols // 2 + w] = 1.0
    return u


def _jacobi_oracle(u: np.ndarray, steps: int) -> np.ndarray:
    for _ in range(steps):
        p = np.pad(u, 1)
        u = 0.25 * (p[:-2, 1:-1] + p[2:, 1:-1] + p[1:-1, :-2] + p[1:-1, 2:])
    return u


def _drill_worker(conn, worker_id: int, seconds_per_cell: float) -> None:
    """Numpy-only Jacobi band worker (module level for mp 'spawn').

    Receives ``("step", band)`` where `band` is the owned rows plus one halo
    row on each side; replies ``(new_rows, elapsed_seconds)``. The synthetic
    per-cell cost is enforced by sleeping out the remainder of
    ``seconds_per_cell * cells`` — a deterministic stand-in for a slow host
    that keeps the drill CI-stable (sleep dominates compute noise)."""
    while True:
        msg = conn.recv()
        if msg[0] == "stop":
            conn.close()
            return
        band = msg[1]
        t0 = time.perf_counter()
        p = np.pad(band, ((0, 0), (1, 1)))
        out = 0.25 * (p[:-2, 1:-1] + p[2:, 1:-1]
                      + p[1:-1, :-2] + p[1:-1, 2:])
        budget = seconds_per_cell * out.size
        time.sleep(max(0.0, budget - (time.perf_counter() - t0)))
        conn.send((out, time.perf_counter() - t0))


def straggler_drill(workers: int = 4, rows: int = 64, cols: int = 64,
                    steps: int = 24, warmup: int = 4,
                    rebalance_every: int = 4, slow_worker: int = 0,
                    slow_factor: float = 3.0,
                    seconds_per_cell: float = 8e-6,
                    dynamic: bool = True,
                    fail_worker: Optional[int] = None,
                    fail_at_step: Optional[int] = None,
                    alpha: float = 0.5) -> Dict:
    """Live dynamic-load-balance drill: `workers` processes each own one row
    band of a Jacobi grid; `slow_worker` runs `slow_factor`x slower per cell.

    Static mode keeps the uniform band cut for the whole run (the two-phase
    analogue: every step waits for the straggler). Dynamic mode records each
    worker's measured per-cell rate into a :class:`CostModel` and re-cuts the
    band extents every `rebalance_every` steps — work migrates away from the
    straggler and step time converges toward the weighted-balance bound.

    If `fail_worker`/`fail_at_step` are set, that worker is terminated
    mid-run and its band is rerouted to a survivor via
    :func:`repro.runtime.ft.reassign_host_shards` — the band decomposition is
    what makes the reroute a pure scheduling change (any survivor can compute
    any band from the current grid).

    Returns throughput (`steps_per_s`, measured after `warmup` steps), the
    cut history, the final band extents, and `max_err` vs a single-process
    oracle (the re-cut never changes the numerics).
    """
    from repro.core.cost import CostModel
    from repro.core.domain import part_extents

    if not 0 < warmup < steps:
        raise ValueError(f"need 0 < warmup < steps, got {warmup}/{steps}")
    if not 0 <= slow_worker < workers:
        raise ValueError(f"slow_worker {slow_worker} out of range")
    if (fail_worker is None) != (fail_at_step is None):
        raise ValueError("fail_worker and fail_at_step go together")

    ctx = mp.get_context("spawn")
    conns, procs = [], []
    for wid in range(workers):
        parent, child = ctx.Pipe()
        rate = seconds_per_cell * (slow_factor if wid == slow_worker else 1.0)
        p = ctx.Process(target=_drill_worker, args=(child, wid, rate),
                        daemon=True)
        p.start()
        child.close()
        conns.append(parent)
        procs.append(p)

    cost = CostModel(alpha=alpha)
    u = _drill_init(rows, cols)
    extents = part_extents(rows, workers, None)
    cut_history = [extents]
    # band -> computing worker; identity until a failure reroutes
    owner = {b: b for b in range(workers)}
    failed: List[int] = []
    t_measured = None
    try:
        for step in range(steps):
            if fail_at_step is not None and step == fail_at_step and not failed:
                from repro.runtime.ft import reassign_host_shards

                procs[fail_worker].terminate()
                conns[fail_worker].close()
                failed.append(fail_worker)
                assignment = reassign_host_shards(workers, failed)
                owner = {b: s for s, bands in assignment.items()
                         for b in bands}
            if step == warmup:
                t_measured = time.perf_counter()

            ranges = _extents_to_ranges(extents)
            new_u = np.empty_like(u)
            # survivors run their own band in parallel; rerouted bands go out
            # in later waves (a survivor serves its extra bands sequentially)
            waves: Dict[int, List[int]] = {}
            for band, srv in owner.items():
                waves.setdefault(srv, []).append(band)
            depth = max(len(v) for v in waves.values())
            for wave in range(depth):
                sent = []
                for srv, bands in waves.items():
                    if wave >= len(bands):
                        continue
                    band = bands[wave]
                    a, b = ranges[band]
                    top = u[a - 1:a] if a > 0 else np.zeros((1, cols),
                                                            u.dtype)
                    bot = u[b:b + 1] if b < rows else np.zeros((1, cols),
                                                               u.dtype)
                    conns[srv].send(
                        ("step", np.concatenate([top, u[a:b], bot])))
                    sent.append((srv, band, a, b))
                for srv, band, a, b in sent:
                    out, elapsed = conns[srv].recv()
                    new_u[a:b] = out
                    cost.record((band,), elapsed, cells=(b - a) * cols)
            u = new_u

            recut = (dynamic and rebalance_every > 0
                     and (step + 1) % rebalance_every == 0
                     and step + 1 < steps)
            if recut:
                wts = cost.weights_along([ranges])
                new_extents = part_extents(rows, workers, wts[0])
                if new_extents != extents:
                    extents = new_extents
                    cut_history.append(extents)
        elapsed_measured = time.perf_counter() - t_measured
    finally:
        for wid, c in enumerate(conns):
            try:
                c.send(("stop",))
                c.close()
            except (OSError, BrokenPipeError):
                pass
        for p in procs:
            p.join(timeout=10)
            if p.is_alive():
                p.terminate()

    oracle = _jacobi_oracle(_drill_init(rows, cols), steps)
    return {
        "steps_per_s": (steps - warmup) / elapsed_measured,
        "cut_history": cut_history,
        "extents": extents,
        "max_err": float(np.abs(u - oracle).max()),
        "failed": failed,
        "owner": owner,
        "rates": {b: cost.ema((b,)) for b in range(workers)},
    }


def straggler_drill_compare(**kw) -> Dict:
    """Run the drill static then dynamic with identical skew; returns both
    results plus ``speedup`` = dynamic / static steps-per-second."""
    static = straggler_drill(dynamic=False, **kw)
    dynamic = straggler_drill(dynamic=True, **kw)
    return {"static": static, "dynamic": dynamic,
            "speedup": dynamic["steps_per_s"] / static["steps_per_s"]}
