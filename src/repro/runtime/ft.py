"""Fault-tolerance controller: restart-on-failure, elastic re-mesh, and
straggler-absorbing data reassignment.

The paper's load-balancing argument (tasks absorb imbalance) becomes, at
cluster scale, *restartability*: a failed step must be retryable without
losing more than `checkpoint_every` steps, and a lost pod must be absorbable
by re-meshing. Both paths reduce to "restore the latest atomic checkpoint and
continue from its data step" — possible because the data pipeline is a pure
function of the step index.
"""
from __future__ import annotations

import logging
from typing import Callable, Dict, List, Optional, Sequence

from repro.runtime.trainer import Trainer

log = logging.getLogger(__name__)


def reassign_host_shards(num_hosts: int, failed: Sequence[int]
                         ) -> Dict[int, List[int]]:
    """Straggler/failure mitigation at the data level: the batch slices owned
    by failed (or persistently slow) hosts are redistributed round-robin over
    the survivors — the HDOT over-decomposition of the batch axis is what
    makes the slices reassignable without any data movement (each host can
    materialize ANY slice from the step index alone, data/pipeline.py).

    Returns {surviving_host: [host_slice_ids it now serves]}."""
    if num_hosts < 1:
        raise ValueError(f"num_hosts must be >= 1, got {num_hosts}")
    failed_set = set(failed)
    bad = sorted(h for h in failed_set if not 0 <= h < num_hosts)
    if bad:
        raise ValueError(
            f"failed host ids {bad} out of range for num_hosts={num_hosts}")
    survivors = [h for h in range(num_hosts) if h not in failed_set]
    if not survivors:
        raise RuntimeError("no surviving hosts")
    out: Dict[int, List[int]] = {h: [h] for h in survivors}
    for i, lost in enumerate(sorted(failed_set)):
        out[survivors[i % len(survivors)]].append(lost)
    return out


class FaultTolerantRunner:
    def __init__(self, trainer_factory: Callable[[], Trainer],
                 max_restarts: int = 3):
        if max_restarts < 0:
            raise ValueError(f"max_restarts must be >= 0, got {max_restarts}")
        self.trainer_factory = trainer_factory
        self.max_restarts = max_restarts
        self.restarts = 0

    def run(self, total_steps: int,
            failure_hook: Optional[Callable[[int], None]] = None) -> Trainer:
        """Run to `total_steps`, restarting from the latest checkpoint on any
        exception (up to max_restarts). Returns the final trainer."""
        trainer = self.trainer_factory()
        while True:
            try:
                if trainer.params is None:
                    trainer.restore_if_available()
                remaining = total_steps - trainer.step
                if remaining <= 0:
                    return trainer
                trainer.train(remaining, failure_hook=failure_hook)
                return trainer
            except Exception as e:  # noqa: BLE001 - controller must catch all
                self.restarts += 1
                log.warning("step failed (%s); restart %d/%d",
                            e, self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                # fresh trainer: re-reads the latest atomic checkpoint
                trainer = self.trainer_factory()
