"""Training/serving runtime. Lazy re-exports: the straggler drill's
multiprocessing workers import :mod:`repro.runtime.rebalance` in spawned
children, and eagerly importing the Trainer here would drag jax (seconds of
init) into every numpy-only worker."""

__all__ = ["Trainer", "BatchServer", "FaultTolerantRunner"]

_HOMES = {
    "Trainer": "repro.runtime.trainer",
    "BatchServer": "repro.runtime.server",
    "FaultTolerantRunner": "repro.runtime.ft",
}


def __getattr__(name):
    if name in _HOMES:
        import importlib

        return getattr(importlib.import_module(_HOMES[name]), name)
    raise AttributeError(f"module 'repro.runtime' has no attribute {name!r}")
