from repro.runtime.trainer import Trainer
from repro.runtime.server import BatchServer
from repro.runtime.ft import FaultTolerantRunner

__all__ = ["Trainer", "BatchServer", "FaultTolerantRunner"]
