"""Training runtime: sharded step, microbatch accumulation (HDOT subdomains of
the global batch), checkpoint/restart, elastic re-mesh.

The step function is jitted with donated param/opt buffers. With a DP-only
mesh (every non-dp axis trivial), the loss/grad computation runs under
shard_map over the DP axes and gradient
reduction is the EXPLICIT schedule from core.overlap — ParallelConfig.overlap
picks the zero-copy bucketed HDOT sync (per-bucket multi-operand all-reduces
free to interleave with backward compute) or the monolithic two-phase
baseline, and ParallelConfig.grad_buckets sets the over-decomposition degree.
Without a mesh — or on a mesh with a non-trivial TP axis, where replicating
params inside shard_map would break the TP layout — the partitioner reduces
implicitly (GSPMD). On a 1-device CPU mesh the same code runs unsharded
(tests).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.checkpoint.elastic import shardings_for
from repro.config.base import RunConfig
from repro.core.cost import CostModel
from repro.core.overlap import accumulate_grads, fsdp_unshard_full, grad_sync
from repro.data.pipeline import SyntheticLMDataset
from repro.models.model import ModelOptions, build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine
from repro.sharding.rules import use_sharding

PyTree = Any


class Trainer:
    def __init__(self, run: RunConfig, mesh=None,
                 options: Optional[ModelOptions] = None,
                 dataset: Optional[SyntheticLMDataset] = None):
        self.run = run
        self.mesh = mesh
        self.opt_cfg = AdamWConfig(
            lr=run.train.lr, beta1=run.train.beta1, beta2=run.train.beta2,
            eps=run.train.eps, weight_decay=run.train.weight_decay,
            grad_clip=run.train.grad_clip)
        self.options = options or ModelOptions(
            attn_impl="dense", scan_layers=run.parallel.scan_layers,
            remat=run.parallel.remat,
            moe_a2a_chunks=run.parallel.moe_a2a_chunks)
        self.model = build_model(run.model, self.options)
        self.data = dataset or SyntheticLMDataset(
            vocab_size=run.model.vocab_size, seq_len=run.train.seq_len,
            global_batch=run.train.global_batch, seed=run.train.seed)
        self.ckpt = AsyncCheckpointer(run.train.checkpoint_dir,
                                      keep=run.train.keep_checkpoints)
        self.step = 0
        self.params: Optional[PyTree] = None
        self.opt_state: Optional[PyTree] = None
        self._jit_step = None
        # ZeRO-3: params/opt live as bucket-wise flat buffers sharded over
        # the DP axes (see core.overlap.FsdpLayout); None = replicated state
        self._fsdp_layout = None
        self.metrics_log: list = []
        # measured-cost model for dynamic re-partitioning: per-step wall
        # clock recorded OUTSIDE jit, keyed by this process's index so a
        # multi-host controller can marginalize stragglers out. The hook
        # fires every ParallelConfig.rebalance_every steps (0 = never) and
        # is where a driver re-cuts its decomposition from the EMAs.
        self.cost_model = CostModel()
        self.rebalance_hook: Optional[Callable[[CostModel, int], None]] = None

    # ------------------------------------------------------------------ setup
    def _ctx(self):
        if self.mesh is None:
            import contextlib

            return contextlib.nullcontext()
        return use_sharding(self.mesh)

    def init_state(self, seed: Optional[int] = None) -> None:
        rng = jax.random.PRNGKey(self.run.train.seed if seed is None else seed)
        with self._ctx():
            if self.run.parallel.param_shard:
                from repro.launch.steps import fsdp_init_state

                self.params, self.opt_state, self._fsdp_layout = (
                    fsdp_init_state(self.model, self.run.parallel, self.mesh,
                                    rng))
                return
            params = self.model.init(rng)
            if self.mesh is not None:
                sh = shardings_for(params, self.model.param_axes(), self.mesh)
                params = jax.tree.map(jax.device_put, params, sh)
            self.params = params
            self.opt_state = adamw_init(params)

    def full_params(self) -> PyTree:
        """The parameter tree, reassembled from the ZeRO-3 flat shards when
        param_shard is on (tests/oracles; the hot path never gathers
        outside the step)."""
        if self._fsdp_layout is None:
            return self.params
        return fsdp_unshard_full(self.params, self._fsdp_layout)

    def _build_step(self) -> Callable:
        run = self.run
        model = self.model
        opt_cfg = self.opt_cfg
        accum = run.parallel.accum_steps
        mesh = self.mesh
        # mesh axes that carry data parallelism: explicit HDOT grad-sync runs
        # over exactly these. The explicit schedule treats params as
        # replicated (or ZeRO-3 flat-sharded) inside shard_map, which is only
        # faithful on DP-only meshes: any non-trivial extra axis (TP over
        # 'model') must keep the GSPMD path.
        from repro.launch.steps import explicit_sync_axes, make_fsdp_train_step

        sync_axes, explicit_sync = explicit_sync_axes(run.parallel, mesh)

        if run.parallel.param_shard:
            # ZeRO-3 composition: bucket-wise all-gather / reduce-scatter
            # around the backward, optimizer on the flat shards (GSPMD keeps
            # the elementwise update partitioned). fsdp_init_state already
            # validated the mesh; layout is shared with the state buffers.
            step_fn = make_fsdp_train_step(
                model, run.parallel, mesh, opt_cfg,
                warmup_steps=run.train.warmup_steps,
                total_steps=run.train.total_steps,
                layout=self._fsdp_layout)
            return jax.jit(step_fn, donate_argnums=(0, 1))

        def loss_and_grad(params, batch):
            return jax.value_and_grad(model.train_loss)(params, batch)

        def grads_fn(params, batch):
            if not explicit_sync:
                return accumulate_grads(loss_and_grad, params, batch, accum)

            # Explicit-schedule path: shard_map over the DP axes so the
            # gradient reduction is the bucketed zero-copy HDOT sync from
            # core.overlap (or the monolithic two-phase baseline) instead of
            # a partitioner-chosen collective.
            from jax.sharding import PartitionSpec as P

            n_shards = 1
            for a in sync_axes:
                n_shards *= mesh.shape[a]
            # layer provenance: cut buckets on layer boundaries and emit
            # them last-backward-first (ParallelConfig.bucket_order)
            layers = (model.param_layers()
                      if run.parallel.bucket_order == "reverse_topo" else None)

            def local(p, b):
                from repro.sharding.rules import no_sharding

                # manual region: logical sharding constraints must be inert
                with no_sharding():
                    loss, g = accumulate_grads(loss_and_grad, p, b, accum)
                g = grad_sync(g, sync_axes, mode=run.parallel.overlap,
                              num_buckets=run.parallel.grad_buckets,
                              layers=layers, order=run.parallel.bucket_order)
                # psum of per-shard mean-grads -> global mean over all shards
                g = jax.tree.map(lambda x: x / n_shards, g)
                return jax.lax.pmean(loss, sync_axes), g

            batch_specs = jax.tree.map(
                lambda x: P(sync_axes, *([None] * (x.ndim - 1))), batch)
            # check_vma off: train_loss carries internal sharding constraints
            # (with_logical) the replication checker has no rule for
            return jax.shard_map(
                local, mesh=mesh, in_specs=(P(), batch_specs),
                out_specs=(P(), P()), check_vma=False)(params, batch)

        def step_fn(params, opt_state, batch):
            loss, grads = grads_fn(params, batch)
            lr = warmup_cosine(opt_state["step"], opt_cfg.lr,
                               run.train.warmup_steps, run.train.total_steps)
            params, opt_state, gnorm = adamw_update(grads, opt_state, params,
                                                    opt_cfg, lr)
            return params, opt_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

        # params and optimizer state are donated: the bucketed sync and the
        # optimizer update run in place on the gradient/param buffers
        return jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------------- loop
    def restore_if_available(self) -> bool:
        d = self.run.train.checkpoint_dir
        if latest_step(d) is None:
            return False
        if self.params is None:
            self.init_state()
        target = {"params": self.params, "opt": self.opt_state}
        _, tree, extra = restore_checkpoint(d, target)
        if self.mesh is not None and self._fsdp_layout is not None:
            # ZeRO-3 state: params AND optimizer moments go back to their
            # P(dp_axes) shards (mirrors fsdp_init_state — otherwise the
            # restored moments sit replicated and 1/|dp| residency is lost)
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.launch.steps import explicit_sync_axes

            sync_axes, _ = explicit_sync_axes(self.run.parallel, self.mesh)
            sharding = NamedSharding(self.mesh, P(sync_axes))
            tree["params"] = {k: jax.device_put(v, sharding)
                              for k, v in tree["params"].items()}
            for mom in ("m", "v"):
                tree["opt"][mom] = {k: jax.device_put(v, sharding)
                                    for k, v in tree["opt"][mom].items()}
        elif self.mesh is not None:
            sh = {
                "params": shardings_for(self.params, self.model.param_axes(), self.mesh),
                "opt": None,
            }
            tree["params"] = jax.tree.map(jax.device_put, tree["params"], sh["params"])
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = int(extra.get("data_step", 0))
        return True

    def save(self) -> None:
        self.ckpt.save(self.step, {"params": self.params, "opt": self.opt_state},
                       extra={"data_step": self.step,
                              "data": self.data.state(self.step)})

    def _augment_frontend(self, batch: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
        """Modality-frontend STUBS per the brief: encdec/vlm batches carry
        precomputed frame/patch embeddings (deterministic constants here)."""
        cfg = self.run.model
        b = batch["tokens"].shape[0]
        if cfg.family == "encdec" and "frames" not in batch:
            batch = dict(batch)
            batch["frames"] = np.full((b, cfg.encdec.enc_seq, cfg.d_model),
                                      0.02, np.float32)
        if cfg.family == "vlm" and "patches" not in batch:
            batch = dict(batch)
            batch["patches"] = np.full((b, cfg.num_vision_patches, cfg.d_model),
                                       0.02, np.float32)
        return batch

    def _place_batch(self, batch: Dict[str, np.ndarray]) -> Dict[str, jax.Array]:
        if self.mesh is None:
            return {k: jnp.asarray(v) for k, v in batch.items()}
        from repro.sharding.rules import ShardingContext, resolve_pspec
        from jax.sharding import NamedSharding

        ctx = ShardingContext(self.mesh)
        out = {}
        for k, v in batch.items():
            axes = ("batch",) + (None,) * (v.ndim - 1)
            out[k] = jax.device_put(
                v, NamedSharding(self.mesh, resolve_pspec(v.shape, axes, ctx)))
        return out

    def train(self, num_steps: int,
              failure_hook: Optional[Callable[[int], None]] = None) -> Dict:
        """Run `num_steps` steps from the current position. `failure_hook` lets
        tests inject faults (raises) at chosen steps."""
        if self.params is None:
            if not self.restore_if_available():
                self.init_state()
        if self._jit_step is None:
            self._jit_step = self._build_step()
        t0 = time.time()
        rebalance_every = self.run.parallel.rebalance_every
        proc_key = (jax.process_index(),)
        with self._ctx():
            for _ in range(num_steps):
                if failure_hook is not None:
                    failure_hook(self.step)
                batch = self._place_batch(
                    self._augment_frontend(self.data.batch_at(self.step)))
                ts = time.perf_counter()
                self.params, self.opt_state, metrics = self._jit_step(
                    self.params, self.opt_state, batch)
                # float() blocks on the step's outputs, so the measured span
                # is real compute, not async dispatch latency
                metrics = {k: float(v) for k, v in metrics.items()}
                self.cost_model.record(
                    proc_key, time.perf_counter() - ts,
                    cells=self.run.train.global_batch)
                self.step += 1
                if (rebalance_every and self.rebalance_hook is not None
                        and self.step % rebalance_every == 0):
                    self.rebalance_hook(self.cost_model, self.step)
                if self.step % self.run.train.checkpoint_every == 0:
                    self.save()
                self.metrics_log.append(metrics | {"step": self.step})
        self.ckpt.wait()
        return {"steps": num_steps, "seconds": time.time() - t0,
                "final": self.metrics_log[-1] if self.metrics_log else {}}
