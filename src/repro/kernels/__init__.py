"""Pallas TPU kernels for the perf-critical compute hot-spots.

Each kernel package provides:
  <name>.py -- pl.pallas_call + BlockSpec VMEM tiling (TPU target)
  ops.py    -- jit'd wrapper with impl dispatch ('ref' | 'pallas' | interpret)
  ref.py    -- pure-jnp oracle (also the CPU execution path for models/tests)

Kernels: flash_attention (GQA/causal/SWA), heat2d (paper's blocked
Gauss-Seidel tile, red-black ordered for the VPU), ssd_scan (Mamba-2 SSD
chunk), lru_scan (RG-LRU gated linear recurrence).
"""
