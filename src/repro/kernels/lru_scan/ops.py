"""jit'd wrapper for the gated linear recurrence (RG-LRU) scan."""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels.lru_scan import ref as _ref


def lru_scan(a, b, h0=None, impl: str = "auto",
             interpret: bool | None = None) -> Tuple[jax.Array, jax.Array]:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref.lru_scan_ref(a, b, h0)
    if impl == "pallas":
        import importlib

        _k = importlib.import_module("repro.kernels.lru_scan.lru_scan")
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _k.lru_scan_pallas(a, b, h0, interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")
