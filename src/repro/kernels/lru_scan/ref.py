"""Pure-jnp oracle for the gated linear recurrence  h_t = a_t*h_{t-1} + b_t
(RG-LRU inner loop, Griffin [arXiv:2402.19427]).

Uses the associative composition (a2,b2)o(a1,b1) = (a1*a2, a2*b1 + b2) so the
oracle itself is parallel (log-depth), matching what the Pallas kernel
computes blockwise.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def lru_scan_ref(a: jax.Array, b: jax.Array,
                 h0: jax.Array | None = None) -> Tuple[jax.Array, jax.Array]:
    """a, b: (batch, seq, width). Returns (h (batch, seq, width), h_last)."""
    if h0 is not None:
        # fold the initial state into the first step: h_1 = a_1*h0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    af, bf = jax.lax.associative_scan(combine, (a.astype(jnp.float32),
                                                b.astype(jnp.float32)), axis=1)
    h = bf
    return h.astype(b.dtype), h[:, -1]


def lru_scan_sequential(a, b, h0=None):
    """O(l) loop ground truth (tests only)."""
    bsz, l, w = a.shape
    h = jnp.zeros((bsz, w), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    out = []
    for t in range(l):
        h = a[:, t].astype(jnp.float32) * h + b[:, t].astype(jnp.float32)
        out.append(h)
    return jnp.stack(out, 1).astype(b.dtype), h
