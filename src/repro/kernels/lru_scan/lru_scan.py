"""Pallas TPU kernel: chunked gated linear recurrence  h_t = a_t*h_{t-1} + b_t.

Grid (batch, chunks) with the chunk axis innermost: TPU grids execute
sequentially, so the carry state lives in VMEM scratch across chunk steps —
exactly the HDOT hand-off between sequence subdomains. Inside the chunk the
recurrence runs as a width-vectorized fori_loop over time (VPU lanes carry the
`width` dimension; the recurrence itself is latency-bound, which is why the
chunked layout matters: it amortizes HBM traffic to one load/store per
element).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h0_ref, o_ref, hlast_ref, h_scr, *, q: int, nc: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)       # (1, w)

    def body(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t[None, :] * h + b_t[None, :]
        o_ref[0, t, :] = h[0].astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, q, body, h_scr[...])
    h_scr[...] = h

    @pl.when(ic == nc - 1)
    def _done():
        hlast_ref[...] = h.astype(hlast_ref.dtype)


def lru_scan_pallas(a: jax.Array, b: jax.Array, h0: Optional[jax.Array] = None,
                    chunk: int = 256,
                    interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """a, b: (batch, seq, width). Returns (h (batch, seq, width), h_last)."""
    bsz, l, w = a.shape
    chunk = min(chunk, l)
    if l % chunk != 0:
        raise ValueError(
            f"lru_scan_pallas: sequence length {l} is not divisible by "
            f"chunk={chunk} (a.shape={a.shape})")
    nc = l // chunk
    if h0 is None:
        h0 = jnp.zeros((bsz, w), jnp.float32)

    kernel = functools.partial(_kernel, q=chunk, nc=nc)
    h, hlast = pl.pallas_call(
        kernel,
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, w), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, chunk, w), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, w), lambda ib, ic: (ib, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, w), lambda ib, ic: (ib, ic, 0)),
            pl.BlockSpec((1, w), lambda ib, ic: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, l, w), b.dtype),
            jax.ShapeDtypeStruct((bsz, w), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return h, hlast
