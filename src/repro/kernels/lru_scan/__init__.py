"""RG-LRU linear-recurrence scan kernel package.

The kernel submodule is imported eagerly BEFORE the function re-export so the
package attribute `lru_scan` deterministically refers to the function.
"""
from repro.kernels.lru_scan import lru_scan as _kernel_module  # noqa: F401
from repro.kernels.lru_scan.ops import lru_scan

__all__ = ["lru_scan"]
