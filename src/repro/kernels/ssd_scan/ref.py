"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) chunked scan.

Math (per head h, state size N, head dim P):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T        (state: P x N)
    y_t = h_t C_t
Chunked evaluation [arXiv:2405.21060 listing 1]: within-chunk term via the
masked C B^T "attention" with decay matrix L, cross-chunk term via a small
recurrence over per-chunk states. The chunk is the HDOT task-level subdomain
of the sequence; the cross-chunk state hand-off is its halo.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def segsum(dA: jax.Array) -> jax.Array:
    """dA: (..., q) -> L log-decay matrix (..., q, q):
    out[i,j] = sum_{j<k<=i} dA_k for j<=i, -inf otherwise."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunk_terms(xc, dtc, A, Bc, Cc):
    """Per-chunk quantities. Shapes (b=batch, c=chunks, q=chunk, h, p, n):
       xc (b,c,q,h,p)  dtc (b,c,q,h)  A (h,)  Bc,Cc (b,c,q,n)
    Returns Y_diag (b,c,q,h,p), states (b,c,h,p,n), decays:
       decay_chunk (b,c,h)  decay_in (b,c,q,h).

    All terms accumulate in f32 regardless of input dtype (matching the
    Pallas kernel): with bf16 intermediates, XLA-CPU's threaded reduction
    order makes the low bits run-to-run dependent, which showed up as the
    mamba2 prefill/decode flake — f32 accumulation keeps that noise ~2^-23,
    orders of magnitude under every tolerance."""
    xc = xc.astype(jnp.float32)
    dtc = dtc.astype(jnp.float32)
    Bc = Bc.astype(jnp.float32)
    Cc = Cc.astype(jnp.float32)
    dA = dtc * A                                                   # (b,c,q,h)
    L = jnp.exp(segsum(jnp.moveaxis(dA, -1, -2)))                  # (b,c,h,q,q)
    att = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)                    # (b,c,q,k)
    xdt = xc * dtc[..., None]                                      # (b,c,q,h,p)
    y_diag = jnp.einsum("bcqk,bchqk,bckhp->bcqhp", att, L, xdt)

    cs = jnp.cumsum(dA, axis=2)                                    # (b,c,q,h)
    total = cs[:, :, -1:, :]                                       # (b,c,1,h)
    decay_states = jnp.exp(total - cs)                             # (b,c,q,h)
    states = jnp.einsum("bckn,bckh,bckhp->bchpn", Bc, decay_states, xdt)
    decay_chunk = jnp.exp(total[:, :, 0, :])                       # (b,c,h)
    decay_in = jnp.exp(cs)                                         # (b,c,q,h)
    return y_diag, states, decay_chunk, decay_in


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, chunk: int,
            initial_state: jax.Array | None = None,
            unroll_chunks: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x (b,l,h,p), dt (b,l,h) [post-softplus], A (h,) [negative],
    B,C (b,l,n). Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, (l, chunk)
    c, q = l // chunk, chunk
    xc = x.reshape(b, c, q, h, p)
    dtc = dt.reshape(b, c, q, h)
    Bc = B.reshape(b, c, q, n)
    Cc = C.reshape(b, c, q, n)

    y_diag, states, decay_chunk, decay_in = ssd_chunk_terms(xc, dtc, A, Bc, Cc)

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st_c, dec_c = inp                                          # (b,h,p,n),(b,h)
        prev = carry
        new = prev * dec_c[..., None, None] + st_c.astype(jnp.float32)
        return new, prev

    if unroll_chunks:  # analysis lowering: FLOPs of every chunk visible
        prevs = []
        carry = s0
        for i in range(c):
            carry, prev = step(carry, (states[:, i], decay_chunk[:, i]))
            prevs.append(prev)
        prev_states = jnp.stack(prevs, axis=1)                     # (b,c,h,p,n)
        final = carry
    else:
        final, prev_states = jax.lax.scan(
            step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decay_chunk, 1, 0)))
        prev_states = jnp.moveaxis(prev_states, 0, 1)

    # off-diagonal term in f32 too: downcasting the states/decays to bf16
    # here was the other half of the flake's noise floor
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc.astype(jnp.float32),
                       prev_states, decay_in)
    y = (y_diag + y_off).reshape(b, l, h, p).astype(x.dtype)
    return y, final


def ssd_sequential(x, dt, A, B, C, initial_state=None):
    """O(l) sequential recurrence — ground truth for validating the chunked
    algorithm itself (tests only; slow)."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    st = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))
    ys = []
    for t in range(l):
        dA = jnp.exp(dt[:, t] * A)                                 # (b,h)
        inp = jnp.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t].astype(jnp.float32),
                         B[:, t].astype(jnp.float32))
        st = st * dA[..., None, None] + inp
        ys.append(jnp.einsum("bhpn,bn->bhp", st, C[:, t].astype(jnp.float32)))
    return jnp.stack(ys, axis=1).astype(x.dtype), st


def ssd_decode_step_ref(state, x_t, dt_t, A, B_t, C_t):
    """One-token recurrence. state (b,h,p,n); x_t (b,h,p); dt_t (b,h);
    B_t,C_t (b,n). Returns (y (b,h,p), new state)."""
    dA = jnp.exp(dt_t * A)                                         # (b,h)
    inp = jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t.astype(jnp.float32),
                     B_t.astype(jnp.float32))
    new = state.astype(jnp.float32) * dA[..., None, None] + inp
    y = jnp.einsum("bhpn,bn->bhp", new, C_t.astype(jnp.float32))
    return y.astype(x_t.dtype), new
