"""Pallas TPU kernel: Mamba-2 SSD within-chunk terms.

One grid step = one (batch, chunk, head) task-level subdomain. The kernel
computes the chunk-local quantities (decay matrix L via segsum, the masked
C B^T "attention" matmul on the MXU, the chunk input-state contribution); the
tiny cross-chunk recurrence (c steps over a (p, n) state) and the off-diagonal
C @ state matmul run in jnp outside — the state hand-off is the sequence
halo between subdomains.

VMEM per step ~ q*p + 2*q*n + 2*q*q floats; defaults (q=256, p=64, n=128)
~ 0.9 MB. q x q and q x n tiles are MXU-aligned (multiples of 128 for n,
q chosen as a multiple of 128 in production configs).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
            ydiag_ref, states_ref, decayin_ref):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)          # (q, p)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)           # (q,)
    A = a_ref[0, 0]                                        # scalar
    B = b_ref[0, 0].astype(jnp.float32)                   # (q, n)
    C = c_ref[0, 0].astype(jnp.float32)                   # (q, n)
    q = x.shape[0]

    dA = dt * A                                            # (q,)
    cs = jnp.cumsum(dA)                                    # (q,)
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)            # (q, q)

    att = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())))   # (q, q)
    xdt = x * dt[:, None]                                  # (q, p)
    ydiag_ref[0, 0, :, 0, :] = (att * L @ xdt).astype(ydiag_ref.dtype)

    decay_states = jnp.exp(cs[-1] - cs)                    # (q,)
    st = jax.lax.dot_general(B * decay_states[:, None], xdt,
                             (((0,), (0,)), ((), ())))     # (n, p)
    states_ref[0, 0, 0, :, :] = st.astype(states_ref.dtype)
    decayin_ref[0, 0, :, 0] = jnp.exp(cs).astype(decayin_ref.dtype)


def ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
               C: jax.Array, chunk: int, initial_state=None,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Same contract as kernels.ssd_scan.ref.ssd_ref."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0
    c, q = l // chunk, chunk
    xc = x.reshape(b, c, q, h, p)
    dtc = dt.reshape(b, c, q, h)
    Bc = B.reshape(b, c, q, n)
    Cc = C.reshape(b, c, q, n)
    A2 = jnp.broadcast_to(A.astype(jnp.float32)[None, :], (1, h))

    y_diag, states, decay_in = pl.pallas_call(
        _kernel,
        grid=(b, c, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda ib, ic, ih: (ib, ic, 0, ih)),
            pl.BlockSpec((1, 1), lambda ib, ic, ih: (0, ih)),
            pl.BlockSpec((1, 1, q, n), lambda ib, ic, ih: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda ib, ic, ih: (ib, ic, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda ib, ic, ih: (ib, ic, 0, ih, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda ib, ic, ih: (ib, ic, ih, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda ib, ic, ih: (ib, ic, 0, ih)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, c, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, c, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((b, c, q, h), jnp.float32),
        ],
        interpret=interpret,
    )(xc, dtc, A2, Bc, Cc)

    decay_chunk = decay_in[:, :, -1, :]                    # (b, c, h)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(carry, inp):
        st_c, dec_c = inp                                  # (b,h,n,p), (b,h)
        prev = carry
        new = prev * dec_c[..., None, None] + jnp.swapaxes(st_c, -1, -2)
        return new, prev

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decay_chunk, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (b,c,h,p,n)

    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc.astype(jnp.float32),
                       prev_states, decay_in)
    y = (y_diag + y_off).reshape(b, l, h, p).astype(x.dtype)
    return y, final
