"""jit'd wrapper for the SSD scan with implementation dispatch.

impl:
  'ref'      pure-jnp chunked oracle (CPU default; also the GSPMD/dry-run path)
  'pallas'   TPU Pallas kernel for the within-chunk terms (interpret=True on CPU)
  'auto'     pallas on TPU backends, ref elsewhere
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels.ssd_scan import ref as _ref


def _backend() -> str:
    return jax.default_backend()


def ssd(x, dt, A, B, C, chunk: int, initial_state=None, impl: str = "auto",
        unroll_chunks: bool = False, interpret: bool | None = None
        ) -> Tuple[jax.Array, jax.Array]:
    if impl == "auto":
        impl = "pallas" if _backend() == "tpu" else "ref"
    # pad ragged tails to a chunk multiple with dt=0 steps: decay exp(0*A)=1
    # and input dt*Bx=0, so the final state is exact and y[:, l:] is sliced off
    l = x.shape[1]
    pad = (-l) % chunk
    if pad:
        import jax.numpy as jnp

        padded = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        y, state = ssd(padded(x), padded(dt), A, padded(B), padded(C), chunk,
                       initial_state, impl, unroll_chunks, interpret)
        return y[:, :l], state
    if impl == "ref":
        return _ref.ssd_ref(x, dt, A, B, C, chunk, initial_state, unroll_chunks)
    if impl == "pallas":
        from repro.kernels.ssd_scan import ssd_scan as _k

        if interpret is None:
            interpret = _backend() != "tpu"
        return _k.ssd_pallas(x, dt, A, B, C, chunk, initial_state,
                             interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")


def ssd_decode_step(state, x_t, dt_t, A, B_t, C_t):
    return _ref.ssd_decode_step_ref(state, x_t, dt_t, A, B_t, C_t)
