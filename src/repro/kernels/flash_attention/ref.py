"""Pure-jnp oracle for blocked causal/SWA GQA attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """q: (b, sq, hq, d); k, v: (b, sk, hkv, d); positions are arange.
    Returns (b, sq, hq, d)."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    s = jnp.einsum("bshgd,bthd->bhgst", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(d)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    m = jnp.ones((sq, sk), bool)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgst,bthd->bshgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)
