"""Flash attention kernel package.

The kernel submodule is imported eagerly BEFORE the function re-export so the
package attribute `flash_attention` deterministically refers to the function
(submodule import would otherwise overwrite it on first lazy use).
"""
from repro.kernels.flash_attention import flash_attention as _kernel_module  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention

__all__ = ["flash_attention"]
