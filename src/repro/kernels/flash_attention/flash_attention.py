"""Pallas TPU flash attention (fwd): blocked online-softmax, causal + sliding
window, GQA by index-mapped KV heads.

TPU adaptation of FlashAttention [arXiv:2205.14135]: the (block_q, block_k)
score tile lives in VMEM and feeds the MXU; the running (m, l, acc) statistics
are VMEM scratch persisting across the sequential kv-block grid dimension
(TPU grids iterate sequentially, which *is* the flash inner loop — no atomics
or shared-memory tricks needed, cf. DESIGN.md hardware-adaptation notes).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks), kv innermost.
VMEM per step ~ block_q*d + 2*block_k*d + block_q*block_k floats; defaults
(block 512, d 128) ~ 1.4 MB << 16 MB VMEM, MXU-aligned (multiples of 128).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, num_kv_blocks: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale   # (bq, bk)

    q_pos = iq * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_scr[...]                                    # (bq, 1)
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == num_kv_blocks - 1)
    def _done():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, window: Optional[int] = None,
                           block_q: int = 512, block_k: int = 512,
                           interpret: bool = False) -> jax.Array:
    """q: (b, sq, hq, d); k, v: (b, sk, hkv, d) — contiguous positions."""
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nq, nk = sq // block_q, sk // block_k

    # (b, h, s, d) layout for clean BlockSpecs
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _kernel, scale=1.0 / math.sqrt(d), causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda ib, ih, iq, ik: (ib, ih // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out, 1, 2)
