"""jit'd wrapper for flash attention with impl dispatch."""
from __future__ import annotations

from typing import Optional

import jax

from repro.kernels.flash_attention import ref as _ref


def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    impl: str = "auto", block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None, **_ignored) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref.flash_attention_ref(q, k, v, causal, window)
    if impl == "pallas":
        import importlib

        _k = importlib.import_module("repro.kernels.flash_attention.flash_attention")
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _k.flash_attention_pallas(q, k, v, causal, window,
                                         block_q=block_q, block_k=block_k,
                                         interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")
