"""Pallas TPU kernel: blocked red-black Gauss-Seidel tile sweep.

One grid step = one task-level subdomain (the paper's OmpSs-2 task). The tile
is staged into VMEM together with four halo STRIPS — a (1, Ty) row from the
north/south neighbors and a (Tx, 1) column from the west/east neighbors —
instead of the four full neighbor tiles the first version staged. Per grid
step that is Tx*Ty + 2*Tx + 2*Ty elements of HBM traffic rather than
5*Tx*Ty: ~5x fewer HBM reads for the default 256x256 tile. Pallas blocks
cannot overlap, so the strips are extra index-mapped views of the same array
whose index maps clamp at the domain edge — the clamped strips are masked off
inside the kernel, mirroring the paper's `isBoundary` gating.

Multi-sweep pipeline: all `sweeps` red/black iterations run back-to-back on
the VMEM-resident tile (halo strips frozen at sweep start — block-Jacobi
across tiles, identical to the `ref` oracle), so HBM is touched exactly once
per tile regardless of sweep count.

VMEM: one (Tx, Ty) f32 tile + strips; defaults 256x256 -> ~0.27 MB. The
red/black updates are dense VPU ops over the whole tile (no wave-front
serialization). The (Tx, 1) column strips lane-pad on real hardware; they are
2/Ty of the tile's bytes, so the padding cost is noise next to the 4 tiles
no longer read.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, n_ref, s_ref, w_ref, e_ref,
            hn_ref, hs_ref, hw_ref, he_ref, o_ref, *,
            sweeps: int, tx: int, ty: int, gx: int, gy: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    u = c_ref[...].astype(jnp.float32)                      # (tx, ty)
    # halo strips from neighbor tiles; at the block edge the strip comes from
    # the caller-supplied halo ring instead (zeros = global Dirichlet, or a
    # neighbor SHARD's edge when the block is one subdomain of a 2-D mesh —
    # both axes stage strips, at tile level and at process level)
    north = jnp.where(i > 0, n_ref[...].astype(jnp.float32),          # (1, ty)
                      hn_ref[...].astype(jnp.float32))
    south = jnp.where(i < gx - 1, s_ref[...].astype(jnp.float32),
                      hs_ref[...].astype(jnp.float32))
    west = jnp.where(j > 0, w_ref[...].astype(jnp.float32),           # (tx, 1)
                     hw_ref[...].astype(jnp.float32))
    east = jnp.where(j < gy - 1, e_ref[...].astype(jnp.float32),
                     he_ref[...].astype(jnp.float32))

    ii = jax.lax.broadcasted_iota(jnp.int32, (tx, ty), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (tx, ty), 1)
    red = ((ii + jj) % 2) == 0

    def nb_sum(u):
        up = jnp.concatenate([north, u[:-1, :]], axis=0)
        dn = jnp.concatenate([u[1:, :], south], axis=0)
        lf = jnp.concatenate([west, u[:, :-1]], axis=1)
        rt = jnp.concatenate([u[:, 1:], east], axis=1)
        return up + dn + lf + rt

    # in-VMEM multi-sweep: the tile never round-trips to HBM between sweeps
    for _ in range(sweeps):
        u = jnp.where(red, 0.25 * nb_sum(u), u)
        u = jnp.where(~red, 0.25 * nb_sum(u), u)

    o_ref[...] = u.astype(o_ref.dtype)


def heat2d_sweep_pallas(u: jax.Array, tile: tuple = (256, 256),
                        sweeps: int = 1, interpret: bool = False,
                        halo: tuple | None = None) -> jax.Array:
    """u: (nx, ny) local block (no ghosts). Tiles are the task-level
    subdomains; across tiles the sweep is block-Jacobi exactly like the
    paper's per-task Gauss-Seidel blocks.

    `halo=(north, south, west, east)` optionally supplies the block-level
    ghost ring — shapes (1, ny), (1, ny), (nx, 1), (nx, 1) — staged into the
    edge tiles as their outer strips (frozen for all `sweeps`, matching the
    tile-level block-Jacobi semantics). This is how a (rows x cols) process
    mesh reuses the kernel per shard: the corner-free 2-D exchange delivers
    both axes' edge strips and the kernel stages them exactly like the
    interior tiles' strips. Default None = zeros = global Dirichlet-0."""
    nx, ny = u.shape
    tx, ty = min(tile[0], nx), min(tile[1], ny)
    if nx % tx != 0 or ny % ty != 0:
        raise ValueError(
            f"heat2d: grid shape {u.shape} is not divisible by tile "
            f"{(tx, ty)} (requested tile={tile})")
    gx, gy = nx // tx, ny // ty
    if halo is None:
        hn = hs = jnp.zeros((1, ny), u.dtype)
        hw = he = jnp.zeros((nx, 1), u.dtype)
    else:
        hn, hs, hw, he = halo
        if not (hn.shape == hs.shape == (1, ny)):
            raise ValueError(
                f"heat2d: north/south halo strips must be shape {(1, ny)} "
                f"for grid {u.shape}; got {hn.shape} / {hs.shape}")
        if not (hw.shape == he.shape == (nx, 1)):
            raise ValueError(
                f"heat2d: west/east halo strips must be shape {(nx, 1)} "
                f"for grid {u.shape}; got {hw.shape} / {he.shape}")

    kernel = functools.partial(_kernel, sweeps=sweeps, tx=tx, ty=ty, gx=gx, gy=gy)

    def clamp(v, hi):
        return jnp.clip(v, 0, hi)

    # Strip block shapes address single rows/columns, so their index maps work
    # in units of one row (resp. column): the north strip is absolute row
    # i*tx - 1 (the last row of tile (i-1, j)), the west strip is absolute
    # column j*ty - 1. Edge tiles clamp into the domain and mask in-kernel
    # (selecting the caller-supplied halo ring instead).
    return pl.pallas_call(
        kernel,
        grid=(gx, gy),
        in_specs=[
            pl.BlockSpec((tx, ty), lambda i, j: (i, j)),
            pl.BlockSpec((1, ty), lambda i, j: (clamp(i * tx - 1, nx - 1), j)),
            pl.BlockSpec((1, ty), lambda i, j: (clamp((i + 1) * tx, nx - 1), j)),
            pl.BlockSpec((tx, 1), lambda i, j: (i, clamp(j * ty - 1, ny - 1))),
            pl.BlockSpec((tx, 1), lambda i, j: (i, clamp((j + 1) * ty, ny - 1))),
            pl.BlockSpec((1, ty), lambda i, j: (0, j)),
            pl.BlockSpec((1, ty), lambda i, j: (0, j)),
            pl.BlockSpec((tx, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tx, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tx, ty), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nx, ny), u.dtype),
        interpret=interpret,
    )(u, u, u, u, u, hn, hs, hw, he)
