"""Pure-jnp oracle for the blocked red-black Gauss-Seidel sweep.

Block semantics match the paper's Heat2D solver (§4.1): Gauss-Seidel *within*
a block, Jacobi *across* blocks (neighbor values read from the previous
sweep's halo). Red-black ordering makes the in-block GS data-parallel — the
TPU-native reformulation of the paper's wave-front (DESIGN.md §2): within one
color all updates are independent (VPU-wide), and black sees updated red,
preserving GS convergence semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _neighbor_sum(u: jax.Array) -> jax.Array:
    """Sum of N/S/W/E neighbors for interior of a (n+2, m+2) padded block."""
    return (u[:-2, 1:-1] + u[2:, 1:-1] + u[1:-1, :-2] + u[1:-1, 2:])


def heat2d_sweep_ref(padded: jax.Array, sweeps: int = 1) -> jax.Array:
    """padded: (n+2, m+2) block with halo ghosts. Returns updated (n, m)
    interior after `sweeps` red-black Gauss-Seidel sweeps (halo held fixed)."""
    n, m = padded.shape[0] - 2, padded.shape[1] - 2
    ii = jnp.arange(n)[:, None]
    jj = jnp.arange(m)[None, :]
    red = (ii + jj) % 2 == 0
    u = padded
    for _ in range(sweeps):
        upd = 0.25 * _neighbor_sum(u)
        interior = jnp.where(red, upd, u[1:-1, 1:-1])
        u = u.at[1:-1, 1:-1].set(interior)
        upd = 0.25 * _neighbor_sum(u)
        interior = jnp.where(~red, upd, u[1:-1, 1:-1])
        u = u.at[1:-1, 1:-1].set(interior)
    return u[1:-1, 1:-1]
