"""jit'd wrapper for the blocked red-black Gauss-Seidel sweep."""
from __future__ import annotations


import jax
import jax.numpy as jnp


from repro.kernels.heat2d import ref as _ref


def heat2d_sweep(u: jax.Array, tile=(256, 256), sweeps: int = 1,
                 impl: str = "auto", interpret: bool | None = None,
                 halo=None) -> jax.Array:
    """Red-black GS sweep over a local block. Tiles update block-Jacobi style
    (halo from the previous sweep). `halo=(north, south, west, east)` supplies
    the block's outer ghost ring — shapes (1, ny)/(1, ny)/(nx, 1)/(nx, 1) —
    for use as one subdomain of a 2-D process mesh; None means the global
    Dirichlet-0 boundary."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref_blocked(u, tile, sweeps, halo)
    if impl == "pallas":
        from repro.kernels.heat2d import heat2d as _k

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _k.heat2d_sweep_pallas(u, tile, sweeps, interpret=interpret,
                                      halo=halo)
    raise ValueError(f"unknown impl {impl!r}")


def heat2d_sweep_sharded(u: jax.Array, mesh, axis_names=("rows", "cols"),
                         tile=(256, 256), sweeps: int = 1, impl: str = "auto",
                         interpret: bool | None = None) -> jax.Array:
    """The tile kernel as one level of a 2-D hierarchy: the GLOBAL grid is
    block-decomposed over a (rows x cols) process mesh, each shard exchanges
    both axes' width-1 edge strips (corner-free ppermutes — the 5-point star
    never reads corners), and the kernel stages those strips as its halo ring
    exactly like it stages neighbor-tile strips. Tiles stay the task-level
    subdomains; shards are the process-level ones — the same partition
    scheme, two levels (paper §3.2)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.halo import exchange_halo_nd

    ar, ac = axis_names

    def local(ul):
        (north, south), (west, east) = exchange_halo_nd(
            ul, ((ar, 0), (ac, 1)), width=1, periodic=False)
        return heat2d_sweep(ul, tile, sweeps, impl, interpret,
                            halo=(north, south, west, east))

    # replication check off: jax has no replication rule for pallas_call yet
    # (modern `check_vma` spelling; compat maps it to check_rep on 0.4.x)
    f = jax.shard_map(local, mesh=mesh, in_specs=P(ar, ac),
                      out_specs=P(ar, ac), check_vma=False)
    return jax.jit(f)(u)


def _ref_blocked(u: jax.Array, tile, sweeps: int, halo=None) -> jax.Array:
    """Oracle with identical block semantics to the kernel: per-tile red-black
    GS with halos frozen at sweep start (block-Jacobi across tiles). The
    outer ghost ring is zeros (Dirichlet) or the supplied `halo` strips;
    corner ghosts stay zero — the 5-point star never reads them."""
    nx, ny = u.shape
    tx, ty = min(tile[0], nx), min(tile[1], ny)
    gx, gy = nx // tx, ny // ty
    up = jnp.pad(u, 1)
    if halo is not None:
        north, south, west, east = halo
        up = up.at[0, 1:-1].set(north[0])
        up = up.at[-1, 1:-1].set(south[0])
        up = up.at[1:-1, 0].set(west[:, 0])
        up = up.at[1:-1, -1].set(east[:, 0])
    out = jnp.zeros_like(u)
    for i in range(gx):
        for j in range(gy):
            blk = jax.lax.dynamic_slice(up, (i * tx, j * ty), (tx + 2, ty + 2))
            out = jax.lax.dynamic_update_slice(
                out, _ref.heat2d_sweep_ref(blk, sweeps), (i * tx, j * ty))
    return out
