"""jit'd wrapper for the blocked red-black Gauss-Seidel sweep."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.heat2d import ref as _ref


def heat2d_sweep(u: jax.Array, tile=(256, 256), sweeps: int = 1,
                 impl: str = "auto", interpret: bool | None = None) -> jax.Array:
    """Red-black GS sweep over a local block with Dirichlet-0 outer boundary.
    Tiles update block-Jacobi style (halo from the previous sweep)."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref_blocked(u, tile, sweeps)
    if impl == "pallas":
        from repro.kernels.heat2d import heat2d as _k

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        return _k.heat2d_sweep_pallas(u, tile, sweeps, interpret=interpret)
    raise ValueError(f"unknown impl {impl!r}")


def _ref_blocked(u: jax.Array, tile, sweeps: int) -> jax.Array:
    """Oracle with identical block semantics to the kernel: per-tile red-black
    GS with halos frozen at sweep start (block-Jacobi across tiles)."""
    nx, ny = u.shape
    tx, ty = min(tile[0], nx), min(tile[1], ny)
    gx, gy = nx // tx, ny // ty
    up = jnp.pad(u, 1)
    out = jnp.zeros_like(u)
    for i in range(gx):
        for j in range(gy):
            blk = jax.lax.dynamic_slice(up, (i * tx, j * ty), (tx + 2, ty + 2))
            out = jax.lax.dynamic_update_slice(
                out, _ref.heat2d_sweep_ref(blk, sweeps), (i * tx, j * ty))
    return out
