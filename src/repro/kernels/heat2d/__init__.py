from repro.kernels.heat2d.ops import heat2d_sweep

__all__ = ["heat2d_sweep"]
