"""Learning-rate schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup_steps, 1)
    t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup_steps, warm, cos)
