"""AdamW in pure JAX with ZeRO-sharded states.

Optimizer moments are created with the SAME sharding as their parameters
(which the launcher shards over (pod, data) x model), so m/v are automatically
ZeRO-3 partitioned — the optimizer itself contains no collectives; gradient
reduction happens in the train step (GSPMD FSDP or core.overlap schedules).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: PyTree, moment_dtype=jnp.float32) -> PyTree:
    """moment_dtype=bfloat16 halves optimizer HBM (used by llama3-405b on the
    256-chip mesh, where fp32 moments alone would exceed v5e HBM — DESIGN §4)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads: PyTree, state: PyTree, params: PyTree,
                 cfg: AdamWConfig, lr: jax.Array,
                 chunk_leading: int = 0) -> Tuple[PyTree, PyTree, jax.Array]:
    """Returns (new_params, new_state, grad_norm). lr is the scheduled value.

    chunk_leading > 0: leaves whose leading dim equals it (the scanned layer
    stacks) are updated one slice at a time via lax.map — the HDOT subdomain
    discipline applied to the optimizer phase. Bounds the f32 intermediate
    working set to one layer's worth instead of the whole stacked tensor
    (measured: 106 -> ~30 GB/chip peak for llama3-405b train, EXPERIMENTS §Perf).
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-12))
    step = state["step"] + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        mdt = m.dtype  # preserve moment dtype (may be bf16, see adamw_init)
        g = g.astype(jnp.float32) * scale
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        if chunk_leading and p.ndim >= 2 and p.shape[0] == chunk_leading:
            pp, mm, vv = jax.lax.map(lambda args: upd(*args), (g, m, v, p))
        else:
            pp, mm, vv = upd(g, m, v, p)
        new_p.append(pp)
        new_m.append(mm)
        new_v.append(vv)
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "step": step},
            gnorm)
