from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.optim.compression import (bf16_compress, bf16_decompress,
                                     ef_compress_update, fp8_compress,
                                     fp8_decompress, int8_compress,
                                     int8_decompress, wire_codec)

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "bf16_compress",
    "bf16_decompress",
    "fp8_compress",
    "fp8_decompress",
    "int8_compress",
    "int8_decompress",
    "ef_compress_update",
    "wire_codec",
]
