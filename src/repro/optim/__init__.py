from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import warmup_cosine
from repro.optim.compression import int8_compress, int8_decompress, ef_compress_update

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "warmup_cosine",
    "int8_compress",
    "int8_decompress",
    "ef_compress_update",
]
