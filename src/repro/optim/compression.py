"""Narrow-wire gradient codecs: int8 error-feedback for the cross-pod hop,
plus bf16/fp8 wire codecs (the WIRE-WIDEN lint fix path).

The slow inter-pod link carries gradients quantized to int8 with a per-tensor
scale (4x fewer bytes than fp32, 2x fewer than bf16); the quantization error
is fed back into the next step's gradient (error feedback, cf. 1-bit
SGD/EF-SGD), which keeps SGD/Adam convergence unbiased in practice.

Used by core.reduction.hierarchical_allreduce(compress=..., decompress=...)
— only the cross-pod all-reduce sees compressed payloads; in-pod
reduce-scatter/all-gather stay full precision.

NOTE (summation semantics): the psum over pods adds int32-accumulated int8
payloads with a shared max-scale, so the reduce is exact in the quantized
domain.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401  (backfills lax.axis_size on old jax)

PyTree = Any


def int8_compress(x: jax.Array, axis_name: str | None = None) -> Dict[str, jax.Array]:
    """Quantize to int8 with a per-tensor scale. When `axis_name` is given the
    scale is pmax'd across the axis so every participant shares one scale and
    the subsequent integer psum is exact."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    # int16 payload: the cross-pod psum of int8-valued entries cannot overflow
    # for <= 256 pods (127 * 256 = 32512 < 2^15) and moves HALF the bytes of
    # f32 (the point of compressing the slow hop)
    return {"q": q.astype(jnp.int16), "scale": scale}


def int8_decompress(payload: Dict[str, jax.Array]) -> jax.Array:
    return payload["q"].astype(jnp.float32) * payload["scale"]


def make_crosspod_codec(axis_name: str):
    """(compress, decompress) pair for hierarchical_allreduce: scale is shared
    (pmax) across the pod axis and NOT psum'd (only q is reduced)."""

    def compress(x):
        p = int8_compress(x, axis_name)
        return {"q": p["q"], "scale": p["scale"] * 0.0 + p["scale"]}  # keep tree

    def decompress(p):
        # q was psum'd over the axis; scale was psum'd too -> divide by count
        n = jax.lax.axis_size(axis_name)
        return p["q"].astype(jnp.float32) * (p["scale"] / n)

    return compress, decompress


def ef_compress_update(g: jax.Array, err: jax.Array,
                       axis_name: str | None = None,
                       compress=None, decompress=None,
                       ) -> Tuple[Dict[str, jax.Array], jax.Array]:
    """Error-feedback step: compress (g + err); return (payload, new_err).

    Defaults to the int8 codec; pass any (compress, decompress) pair from
    ``wire_codec`` to error-feed a bf16 or fp8 wire instead."""
    compress = compress or int8_compress
    decompress = decompress or int8_decompress
    target = g.astype(jnp.float32) + err
    payload = compress(target, axis_name)
    new_err = target - decompress(payload)
    return payload, new_err


# --------------------------------------------------------- narrow wire dtypes
# The sanctioned fix path for the linter's WIRE-WIDEN finding (gradients
# crossing a collective wider than the param spec): re-narrow the wire with
# one of these codecs instead of letting XLA's f32 accumulator width leak
# onto the interconnect. bf16 is a pure cast (no scale state, safe to psum
# directly — reduction happens at f32 after decode on each hop); fp8 (e4m3)
# carries a shared per-tensor scale like int8 but is NOT integer-exact under
# psum, so use it on point-to-point / gather hops or with error feedback.
_FP8_DTYPE = jnp.float8_e4m3fn   # 4-bit exponent / 3-bit mantissa
_FP8_MAX = float(jnp.finfo(_FP8_DTYPE).max)   # 448.0


def bf16_compress(x: jax.Array,
                  axis_name: str | None = None) -> Dict[str, jax.Array]:
    del axis_name  # no shared state: bf16 keeps f32's exponent range
    return {"q": x.astype(jnp.bfloat16)}


def bf16_decompress(payload: Dict[str, jax.Array]) -> jax.Array:
    return payload["q"].astype(jnp.float32)


def fp8_compress(x: jax.Array,
                 axis_name: str | None = None) -> Dict[str, jax.Array]:
    """Quantize to float8_e4m3fn with a per-tensor scale (pmax-shared across
    `axis_name`, same contract as int8_compress)."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    if axis_name is not None:
        amax = jax.lax.pmax(amax, axis_name)
    scale = jnp.maximum(amax, 1e-12) / _FP8_MAX
    q = (x.astype(jnp.float32) / scale).astype(_FP8_DTYPE)
    return {"q": q, "scale": scale}


def fp8_decompress(payload: Dict[str, jax.Array]) -> jax.Array:
    return payload["q"].astype(jnp.float32) * payload["scale"]


WIRE_CODECS = {
    "bf16": (bf16_compress, bf16_decompress),
    "fp8": (fp8_compress, fp8_decompress),
    "int8": (int8_compress, int8_decompress),
}


def wire_codec(kind: str):
    """(compress, decompress) pair by wire-dtype name: bf16 | fp8 | int8."""
    try:
        return WIRE_CODECS[kind]
    except KeyError:
        raise ValueError(
            f"unknown wire codec {kind!r}; available: "
            f"{', '.join(sorted(WIRE_CODECS))}") from None
