"""Deterministic, shardable, resumable synthetic LM data pipeline.

Design rules for 1000-node training:
  * STATELESS addressing — `batch_at(step)` is a pure function of (seed, step),
    so exact restart needs only the integer step from the checkpoint, and any
    host can materialize exactly its slice (`host_slice`) without coordination.
  * The stream has learnable structure (noisy affine next-token process) so
    integration tests can assert that optimization actually reduces loss.
  * Domain decomposition of the batch axis reuses repro.core.domain — the same
    scheme that shards the mesh (HDOT level-0).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.core.domain import decompose_grid


@dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    noise: float = 0.1          # fraction of uniformly random next-tokens
    # affine next-token process: x_{t+1} = (a*x_t + b) % V with prob 1-noise.
    # Default a=1 (shift cipher): learnable as one offset in embedding space,
    # so integration tests / examples show a fast visible loss drop; a=31
    # turns it into modular arithmetic (grokking-hard, measured ~flat at 200
    # steps on a 14M model).
    a: int = 1
    b: int = 7

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step, 0xD0D0]))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Full global batch for `step` (tokens + next-token targets)."""
        rng = self._rng(step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        seq = np.empty((B, S + 1), np.int32)
        seq[:, 0] = rng.integers(0, V, B)
        noise_mask = rng.random((B, S)) < self.noise
        noise_tok = rng.integers(0, V, (B, S), dtype=np.int64)
        for t in range(S):
            nxt = (seq[:, t].astype(np.int64) * self.a + self.b) % V
            seq[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {"tokens": seq[:, :-1], "targets": seq[:, 1:]}

    def host_slice(self, step: int, host_id: int, num_hosts: int
                   ) -> Dict[str, np.ndarray]:
        """This host's contiguous batch slice — same decompose_grid scheme the
        mesh uses for the batch axis."""
        boxes = decompose_grid((self.global_batch,), (num_hosts,))
        sl = boxes[host_id].slices()[0]
        full = self.batch_at(step)
        return {k: v[sl] for k, v in full.items()}

    # ------------------------------------------------------------------ state
    def state(self, step: int) -> Dict[str, int]:
        return {"step": int(step), "seed": int(self.seed)}

    @staticmethod
    def resume_step(state: Dict[str, int]) -> int:
        return int(state["step"])
