from repro.data.pipeline import SyntheticLMDataset

__all__ = ["SyntheticLMDataset"]
