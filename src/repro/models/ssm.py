"""Mamba-2 block (SSD, state-space duality) [arXiv:2405.21060].

Separate z/x/B/C/dt projections (rather than one fused in_proj) keep every
weight dim cleanly shardable: d_inner and dt-heads ride the TP axis, the small
state dim replicates. The SSD chunk is the task-level subdomain of the
sequence; cross-chunk state hand-off is its halo (cf. DESIGN.md §2).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.models.layers import ParamSpec, rms_norm
from repro.sharding.rules import with_logical


def ssm_specs(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    assert s is not None
    d = cfg.d_model
    di = s.d_inner(d)
    h = s.num_heads(d)
    n = s.state_dim
    k = s.conv_kernel
    return {
        "wz": ParamSpec((d, di), ("embed", "mlp"), dtype),
        "wx": ParamSpec((d, di), ("embed", "mlp"), dtype),
        "wB": ParamSpec((d, n), ("embed", "state"), dtype),
        "wC": ParamSpec((d, n), ("embed", "state"), dtype),
        "wdt": ParamSpec((d, h), ("embed", "heads"), dtype),
        "dt_bias": ParamSpec((h,), ("heads",), jnp.float32, "zeros"),
        "A_log": ParamSpec((h,), ("heads",), jnp.float32, "zeros"),
        "D": ParamSpec((h,), ("heads",), jnp.float32, "ones"),
        "conv_x": ParamSpec((k, di), ("conv", "mlp"), dtype),
        "conv_B": ParamSpec((k, n), ("conv", "state"), dtype),
        "conv_C": ParamSpec((k, n), ("conv", "state"), dtype),
        "norm": ParamSpec((di,), ("mlp",), jnp.float32, "ones"),
        "wo": ParamSpec((di, d), ("mlp", "embed"), dtype),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           state: Optional[jax.Array] = None) -> jax.Array:
    """x: (b, l, c); w: (k, c). Causal depthwise conv; `state` is the last
    (k-1) inputs from the previous segment (decode/chunk hand-off)."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1]] * w[j]
    return jax.nn.silu(out)


def _project(p, u: jax.Array, cfg: ModelConfig):
    s = cfg.ssm
    z = u @ p["wz"]
    x = u @ p["wx"]
    B = u @ p["wB"]
    C = u @ p["wC"]
    dt = jax.nn.softplus(u.astype(jnp.float32) @ p["wdt"].astype(jnp.float32)
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    del s
    return z, x, B, C, dt, A


def ssm_apply(p, u: jax.Array, cfg: ModelConfig,
              unroll_chunks: bool = False, impl: str = "auto") -> jax.Array:
    """Full-sequence Mamba-2 block. u: (b, l, d)."""
    s = cfg.ssm
    assert s is not None
    b, l, d = u.shape
    z, x, B, C, dt, A = _project(p, u, cfg)
    x = _causal_depthwise_conv(x, p["conv_x"])
    B = _causal_depthwise_conv(B, p["conv_B"])
    C = _causal_depthwise_conv(C, p["conv_C"])
    h = s.num_heads(d)
    xh = x.reshape(b, l, h, s.head_dim)
    xh = with_logical(xh, ("batch", None, "act_heads", None))
    chunk = min(s.chunk_size, l)
    y, _ = ssd_ops.ssd(xh, dt, A, B, C, chunk, impl=impl,
                       unroll_chunks=unroll_chunks)
    y = y + xh * p["D"][:, None].astype(xh.dtype)
    y = y.reshape(b, l, -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["wo"]


# ----------------------------------------------------------------- decode path
def ssm_cache_specs(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    h = s.num_heads(cfg.d_model)
    k = s.conv_kernel
    return {
        "state": ParamSpec((batch, h, s.head_dim, s.state_dim),
                           ("batch", "act_heads", None, None), jnp.float32, "zeros"),
        "conv_x": ParamSpec((batch, k - 1, di), ("batch", None, "mlp"), dtype, "zeros"),
        "conv_B": ParamSpec((batch, k - 1, s.state_dim), ("batch", None, None), dtype, "zeros"),
        "conv_C": ParamSpec((batch, k - 1, s.state_dim), ("batch", None, None), dtype, "zeros"),
    }


def ssm_decode_step(p, u: jax.Array, cfg: ModelConfig, cache: Dict) -> Tuple[jax.Array, Dict]:
    """u: (b, 1, d); cache: see ssm_cache_specs."""
    s = cfg.ssm
    b = u.shape[0]
    z, x, B, C, dt, A = _project(p, u, cfg)

    def conv_step(x1, w, st):
        y = _causal_depthwise_conv(x1, w, state=st)
        new_st = jnp.concatenate([st.astype(x1.dtype), x1], axis=1)[:, 1:]
        return y, new_st

    x, cx = conv_step(x, p["conv_x"], cache["conv_x"])
    B, cB = conv_step(B, p["conv_B"], cache["conv_B"])
    C, cC = conv_step(C, p["conv_C"], cache["conv_C"])

    h = s.num_heads(cfg.d_model)
    xh = x.reshape(b, h, s.head_dim)
    y, new_state = ssd_ops.ssd_decode_step(cache["state"], xh, dt[:, 0], A,
                                           B[:, 0], C[:, 0])
    y = y + xh * p["D"][:, None].astype(xh.dtype)
    y = y.reshape(b, 1, -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]
    return out, {"state": new_state, "conv_x": cx, "conv_B": cB, "conv_C": cC}
