"""Param-spec system + common layers (norms, rope, MLP).

Parameters are plain dict pytrees. Every module publishes a matching tree of
:class:`ParamSpec` (shape + logical sharding axes + initializer), from which we
derive (a) materialized params for real runs, (b) ShapeDtypeStructs +
NamedShardings for the dry-run — the same "declare the decomposition once,
reuse it at every level" discipline HDOT prescribes for domains.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import with_logical

PyTree = Any


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical sharding axes (len == ndim)
    dtype: Any = jnp.bfloat16
    init: str = "normal"                     # normal | zeros | ones
    scale: Optional[float] = None            # None -> 1/sqrt(fan_in)
    # Layer provenance: forward depth of the (sub)module owning this param.
    # Higher depth = closer to the loss = its gradient is ready EARLIER in the
    # backward pass. core.overlap uses it to cut grad-sync buckets along layer
    # boundaries and emit their collectives last-backward-first. A scanned
    # (stacked) layer tree is one depth: lax.scan's backward materializes the
    # whole stacked gradient at once, so there is no per-layer early release
    # to order within it.
    layer: Optional[int] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _leaf_paths(tree: PyTree, prefix=()) -> Dict[Tuple, ParamSpec]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_leaf_paths(tree[k], prefix + (k,)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_leaf_paths(v, prefix + (i,)))
    else:
        out[prefix] = tree
    return out


def init_leaf(key: jax.Array, path: Tuple, spec: ParamSpec) -> jax.Array:
    """Materialize ONE parameter leaf. The leaf's key is derived from its tree
    path rather than traversal order, so initializing any SUBSET of leaves —
    e.g. one FSDP bucket at a time under jit with sharded outputs — is
    bit-identical to the full-tree init."""
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    k = jax.random.fold_in(key, hash(path) % (2**31))
    fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[-1], 1)
    scale = spec.scale if spec.scale is not None else 1.0 / math.sqrt(fan_in)
    n = jax.random.normal(k, spec.shape, jnp.float32)
    # barrier: under jit XLA would merge this scale into normal()'s internal
    # sqrt(2) multiply (one rounding instead of two), so jitted per-bucket
    # init would drift a ulp from the eager full-tree init
    n = jax.lax.optimization_barrier(n)
    return (n * scale).astype(spec.dtype)


def init_from_specs(specs: PyTree, key: jax.Array) -> PyTree:
    """Materialize parameters. Each leaf gets an independent key derived from
    its tree path, so init is insensitive to traversal order."""
    flat = _leaf_paths(specs)
    leaves = {p: init_leaf(key, p, s) for p, s in flat.items()}

    def rebuild(tree, prefix=()):
        if isinstance(tree, dict):
            return {k: rebuild(tree[k], prefix + (k,)) for k in tree}
        if isinstance(tree, (list, tuple)):
            return type(tree)(rebuild(v, prefix + (i,)) for i, v in enumerate(tree))
        return leaves[prefix]

    return rebuild(specs)


def abstract_from_specs(specs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda s: isinstance(s, ParamSpec))


def axes_from_specs(specs: PyTree) -> PyTree:
    return jax.tree.map(lambda s: s.axes, specs,
                        is_leaf=lambda s: isinstance(s, ParamSpec))


def layers_from_specs(specs: PyTree) -> PyTree:
    """Layer-provenance tree (same structure as the params): each leaf's
    forward depth, untagged specs defaulting to depth 0 (the input end, whose
    gradients complete last)."""
    return jax.tree.map(lambda s: 0 if s.layer is None else s.layer, specs,
                        is_leaf=lambda s: isinstance(s, ParamSpec))


def tag_layer(specs: PyTree, depth: int) -> PyTree:
    """Stamp `depth` as the layer provenance of every spec in the subtree."""
    import dataclasses

    return jax.tree.map(lambda s: dataclasses.replace(s, layer=depth), specs,
                        is_leaf=lambda s: isinstance(s, ParamSpec))


# ------------------------------------------------------------------- layers
def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                 # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim))
    emb = jnp.zeros((seq, dim), jnp.float32)
    emb = emb.at[:, 0::2].set(jnp.sin(pos * div))
    emb = emb.at[:, 1::2].set(jnp.cos(pos * div))
    return emb


# ---------------------------------------------------------------- dense MLP
def mlp_specs(d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    return {
        "gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
        "up": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype),
        "down": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype),
    }


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """SwiGLU MLP with TP sharding constraints on the hidden activation."""
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    h = with_logical(h, ("batch", None, "mlp"))
    return h @ p["down"]
