"""Mixture-of-Experts with grouped capacity dispatch (GShard-style groups).

Tokens are processed in GROUPS (one sequence per group) so that all dispatch
bookkeeping (top-k, rank-within-expert cumsum, scatter/gather) happens along
un-sharded dims — groups stay sharded over the DP axes, experts over the TP
axis, and GSPMD inserts the group->expert all-to-all. Expert FLOPs equal the
*active* compute (2*E*C*D*F with E*C ~= tokens*top_k*capacity_factor), so
roofline numbers reflect true MoE economics rather than dense-all-experts.

HDOT view: the expert-capacity buffers are task-level subdomains of the token
domain; the dispatch collective is a per-subdomain communication task that the
scheduler can overlap with the attention compute of neighboring microbatches.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import compat  # noqa: F401  (jax version shims)
from repro.config.base import ModelConfig
from repro.models.layers import ParamSpec
from repro.sharding.rules import with_logical


def moe_specs(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    m = cfg.moe
    if m is None:
        raise ValueError(
            f"moe_specs: config {cfg.name!r} (family={cfg.family!r}) has no "
            f"MoEConfig — only family='moe' configs carry cfg.moe")
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    return {
        "router": ParamSpec((d, e), ("embed", None), jnp.float32),
        "gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp"), dtype),
        "down": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed"), dtype),
    }


def capacity(tokens_per_group: int, num_experts: int, top_k: int,
             capacity_factor: float) -> int:
    return max(top_k, int(math.ceil(tokens_per_group * top_k / num_experts
                                    * capacity_factor)))


def _dispatch_tables(assign: jax.Array, E: int, C: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """assign: (G, T, K) expert ids. Returns
       gather_ids (G, E, C)  token index feeding each expert slot (T = pad),
       slot_rank  (G, T, K)  rank of each assignment within its expert,
       keep       (G, T, K)  capacity mask."""
    G, T, K = assign.shape
    onehot = jax.nn.one_hot(assign.reshape(G, T * K), E, dtype=jnp.int32)   # (G,TK,E)
    ranks = jnp.cumsum(onehot, axis=1) - onehot
    rank = jnp.sum(ranks * onehot, axis=-1)                                  # (G,TK)
    eid = assign.reshape(G, T * K)
    keep = rank < C
    slot = jnp.where(keep, eid * C + rank, E * C)
    token = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K)).reshape(T * K)
    token = jnp.broadcast_to(token, (G, T * K))
    buf = jnp.full((G, E * C + 1), T, jnp.int32)
    buf = buf.at[jnp.arange(G)[:, None], slot].set(token)
    gather_ids = buf[:, :E * C].reshape(G, E, C)
    return gather_ids, rank.reshape(G, T, K), keep.reshape(G, T, K)


def moe_apply(p, x: jax.Array, cfg: ModelConfig,
              a2a_chunks: int = 1) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D). Dispatches to the expert-parallel a2a path when the mesh
    shards experts (E divisible by the model axis); otherwise the dense
    capacity-dispatch below. `a2a_chunks` is the EP dispatch/combine
    over-decomposition degree Q (core.a2a_scan; 1 = monolithic).
    Returns (output, aux load-balancing loss)."""
    m = cfg.moe
    if m is None:
        raise ValueError(
            f"moe_apply: config {cfg.name!r} (family={cfg.family!r}) has no "
            f"MoEConfig — only family='moe' configs carry cfg.moe")
    from repro.sharding.rules import current_context

    ctx = current_context()
    if ctx is not None:
        n = ctx.axis_size("model")
        if n > 1 and m.num_experts % n == 0:
            if x.shape[1] % n == 0:
                return moe_apply_ep(p, x, cfg, ctx, a2a_chunks=a2a_chunks)
            if x.shape[1] == 1 and x.shape[0] % n == 0:
                # decode: a single token per sequence — the BATCH is the
                # token domain; swap it into the seq slot so the same EP
                # dispatch applies (measured: qwen3-moe decode_32k collective
                # bytes, EXPERIMENTS §Perf cell-B addendum)
                y, aux = moe_apply_ep(p, x.swapaxes(0, 1), cfg, ctx,
                                      tokens_on_batch=True,
                                      a2a_chunks=a2a_chunks)
                return y.swapaxes(0, 1), aux
    return moe_apply_dense(p, x, cfg)


def moe_apply_dense(p, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """GSPMD capacity dispatch — groups are sequences (G=B, T=S). The
    reference semantics; also the path for expert counts the mesh cannot
    shard (mixtral's 8 experts on a 16-wide model axis -> expert-TP)."""
    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    C = capacity(S, E, K, m.capacity_factor)

    logits = x.astype(jnp.float32) @ p["router"]                  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, assign = jax.lax.top_k(probs, K)                     # (B,S,K)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

    # aux loss (Switch/GShard): E * sum_e f_e * p_e
    f_e = jnp.mean(jnp.sum(jax.nn.one_hot(assign, E), axis=2), axis=(0, 1))
    p_e = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f_e * p_e) * m.router_aux_loss_coef

    gather_ids, rank, keep = _dispatch_tables(assign, E, C)

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, D), x.dtype)], axis=1)      # T = zero row
    xe = jnp.take_along_axis(x_pad[:, :, None, :],
                             gather_ids.reshape(B, E * C)[:, :, None, None], axis=1)
    xe = xe.reshape(B, E, C, D)
    xe = with_logical(xe, ("batch", "experts", None, None))

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["gate"]))
    h = h * jnp.einsum("becd,edf->becf", xe, p["up"])
    h = with_logical(h, ("batch", "experts", None, "expert_mlp"))
    ye = jnp.einsum("becf,efd->becd", h, p["down"])
    ye = with_logical(ye, ("batch", "experts", None, None))

    # combine: y[g,t] = sum_k keep * w_k * ye[g, e_k, rank_k]
    ye_flat = ye.reshape(B, E * C, D)
    ye_flat = jnp.concatenate([ye_flat, jnp.zeros((B, 1, D), ye.dtype)], axis=1)
    slot = jnp.where(keep, assign * C + rank, E * C)              # (B,S,K)
    picked = jnp.take_along_axis(ye_flat[:, :, None, :],
                                 slot.reshape(B, S * K)[:, :, None, None], axis=1)
    picked = picked.reshape(B, S, K, D)
    w = (weights * keep).astype(picked.dtype)[..., None]
    y = jnp.sum(picked * w, axis=2)
    return y.astype(x.dtype), aux


# ------------------------------------------------------------ expert parallel
def moe_apply_ep(p, x: jax.Array, cfg: ModelConfig, ctx,
                 tokens_on_batch: bool = False,
                 a2a_chunks: int = 1) -> Tuple[jax.Array, jax.Array]:
    """shard_map expert parallelism (§Perf cell B): tokens stay seq-sharded,
    experts stay model-sharded, and the ONLY cross-chip traffic is the
    all-to-all of capacity-bucketed tokens (there and back) — chunked into
    `a2a_chunks` capacity slices by `core.a2a_scan` so slice k+1's dispatch
    and slice k-1's combine overlap slice k's expert FFN.

    HDOT structure: the per-chip dispatch reuses the SAME `_dispatch_tables`
    scheme the dense path uses globally — the process-level partition applied
    one level down, exactly the paper's hierarchical reuse. Without this,
    GSPMD lowers the cross-shard combine gather to replicated (B, S*K, D)
    all-reduces (measured 21 GB/chip/layer for qwen3-moe train_4k)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.a2a_scan import a2a_scan
    from repro.sharding.rules import resolve_pspec

    m = cfg.moe
    B, S, D = x.shape
    E, K = m.num_experts, m.top_k
    n = ctx.axis_size("model")
    if E % n != 0:
        raise ValueError(
            f"moe_apply_ep: num_experts={E} is not divisible by the model "
            f"axis size {n} ({cfg.name!r}); EP shards experts over 'model' — "
            f"use the dense/expert-TP path for this mesh")
    E_loc = E // n
    if x.shape[1] % n != 0:
        token_dim = "batch" if tokens_on_batch else "seq"
        raise ValueError(
            f"moe_apply_ep: token dim ({token_dim}={x.shape[1]}) is not "
            f"divisible by the model axis size {n} ({cfg.name!r}); the EP "
            f"dispatch seq-shards tokens over 'model'")
    # per-shard capacity, sized to the LOCAL token count (dim 1 is sharded
    # over exactly the model axis in both the train and decode layouts) —
    # computed here, outside the shard_map body, so a bad Q fails loudly at
    # trace time instead of deep inside a reshape
    C = capacity(x.shape[1] // n, E, K, m.capacity_factor)
    if a2a_chunks < 1 or C % a2a_chunks != 0:
        raise ValueError(
            f"moe_apply_ep: a2a_chunks={a2a_chunks} must be >=1 and divide "
            f"the expert capacity C={C} (tokens/shard={x.shape[1] // n}, "
            f"num_experts={E}, top_k={K}, "
            f"capacity_factor={m.capacity_factor}, {cfg.name!r})")

    # router in GSPMD-land (weights may be FSDP-sharded over data)
    logits = x.astype(jnp.float32) @ p["router"]                  # (B,S,E)

    if tokens_on_batch:
        # x arrived swapped: dim0 is a single decode step, dim1 the batch.
        # The batch/token dim shards over model (+pod if present).
        bax = None
    else:
        logits = with_logical(logits, ("batch", "seq", None))
        bspec = resolve_pspec((B,), ("batch",), ctx)
        bax = bspec[0] if len(bspec) else None
        if isinstance(bax, tuple) and "model" in bax:
            bax = tuple(a for a in bax if a != "model") or None
        elif bax == "model":
            bax = None

    def body(x, logits, gate, up, down):
        # x: (B_loc, S_loc, D); gate/up/down: (E_loc, ...); logits (B_loc,S_loc,E)
        B_loc, S_loc, _ = x.shape
        probs = jax.nn.softmax(logits, axis=-1)
        weights, assign = jax.lax.top_k(probs, K)                 # (B_loc,S_loc,K)
        weights = weights / jnp.sum(weights, axis=-1, keepdims=True)

        f_e = jnp.mean(jnp.sum(jax.nn.one_hot(assign, E), axis=2), axis=(0, 1))
        p_e = jnp.mean(probs, axis=(0, 1))
        f_e = jax.lax.pmean(f_e, "model")
        p_e = jax.lax.pmean(p_e, "model")
        if bax is not None:
            f_e = jax.lax.pmean(f_e, bax)
            p_e = jax.lax.pmean(p_e, bax)
        aux = E * jnp.sum(f_e * p_e) * m.router_aux_loss_coef

        # task-level dispatch, per chip — same scheme as the dense path,
        # capacity C closed over from the trace-time validation above
        gather_ids, rank, keep = _dispatch_tables(assign, E, C)
        x_pad = jnp.concatenate([x, jnp.zeros((B_loc, 1, D), x.dtype)], axis=1)
        xe = jnp.take_along_axis(
            x_pad[:, :, None, :],
            gather_ids.reshape(B_loc, E * C)[:, :, None, None], axis=1)
        xe = xe.reshape(B_loc, E, C, D)

        # process-level dispatch: a2a the expert-bucketed slots to the owners,
        # over-decomposed along the capacity dim — slice k+1's dispatch and
        # slice k-1's combine ride under slice k's FFN (a2a_chunks=1 emits
        # exactly the old monolithic two-a2a program)
        xs = xe.reshape(B_loc, n, E_loc, C, D)
        xs = jnp.moveaxis(xs, 1, 0)                               # (n, B_loc, E_loc, C, D)

        def ffn(xr, _k):
            # expert FFN over one received capacity slice (flops == active
            # tokens); einsums contract only d/f, never the sliced C dim,
            # so chunking is value-preserving
            Cq = xr.shape[3]
            xf = jnp.moveaxis(xr, 2, 0).reshape(E_loc, n * B_loc * Cq, D)
            h = jax.nn.silu(jnp.einsum("etd,edf->etf", xf, gate))
            h = h * jnp.einsum("etd,edf->etf", xf, up)
            yf = jnp.einsum("etf,efd->etd", h, down)
            # return trip layout (paper Code 11: weighted per-slot partials)
            return jnp.moveaxis(yf.reshape(E_loc, n, B_loc, Cq, D), 0, 2)

        ys = a2a_scan(xs, ffn, "model", chunks=a2a_chunks, dim=3)
        ye = jnp.moveaxis(ys, 0, 1).reshape(B_loc, E * C, D)
        ye = jnp.concatenate([ye, jnp.zeros((B_loc, 1, D), ye.dtype)], axis=1)
        slot = jnp.where(keep, assign * C + rank, E * C)
        picked = jnp.take_along_axis(
            ye[:, :, None, :],
            slot.reshape(B_loc, S_loc * K)[:, :, None, None], axis=1)
        picked = picked.reshape(B_loc, S_loc, K, D)
        w = (weights * keep).astype(picked.dtype)[..., None]
        y = jnp.sum(picked * w, axis=2)
        return y.astype(x.dtype), aux

    fn = jax.shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(bax, "model", None), P(bax, "model", None),
                  P("model"), P("model"), P("model")),
        out_specs=(P(bax, "model", None), P()),
        check_vma=False)
    y, aux = fn(x, logits, p["gate"], p["up"], p["down"])
    return y, aux
