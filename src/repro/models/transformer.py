"""Layer stacks for every assigned family.

One `layer_apply` handles the per-family block composition; the stack runs it
either scanned (uniform layers: compile-time O(1) in depth — the runnable
lowering) or unrolled (per-layer HLO visible — the analysis lowering, and the
only mode for heterogeneous stacks: hybrid patterns, encoder-decoder).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import ParamSpec, layer_norm, mlp_apply, mlp_specs, rms_norm
from repro.sharding.rules import with_logical

PyTree = Any


# ------------------------------------------------------------------ block map
def block_kinds(cfg: ModelConfig) -> List[str]:
    """Per-layer temporal-mixing kind."""
    if cfg.family in ("dense", "vlm"):
        return ["attn"] * cfg.num_layers
    if cfg.family == "moe":
        return ["attn_moe"] * cfg.num_layers
    if cfg.family == "ssm":
        return ["ssm"] * cfg.num_layers
    if cfg.family == "hybrid":
        pat = cfg.hybrid.pattern
        return [("local_attn" if pat[i % len(pat)] == "attn" else "rglru")
                for i in range(cfg.num_layers)]
    if cfg.family == "encdec":
        return ["decoder"] * cfg.num_layers
    raise ValueError(cfg.family)


def uniform_stack(cfg: ModelConfig) -> bool:
    kinds = block_kinds(cfg)
    return all(k == kinds[0] for k in kinds) and cfg.family != "encdec"


# ---------------------------------------------------------------------- specs
def _norm_specs(cfg: ModelConfig, name: str) -> Dict[str, ParamSpec]:
    if cfg.family == "encdec":   # whisper uses LayerNorm w/ bias
        return {name: ParamSpec((cfg.d_model,), (None,), jnp.float32, "ones"),
                name + "_b": ParamSpec((cfg.d_model,), (None,), jnp.float32, "zeros")}
    return {name: ParamSpec((cfg.d_model,), (None,), jnp.float32, "ones")}


def _norm(p, x, cfg: ModelConfig, name: str):
    if cfg.family == "encdec":
        return layer_norm(x, p[name], p[name + "_b"], cfg.norm_eps)
    return rms_norm(x, p[name], cfg.norm_eps)


def layer_specs(cfg: ModelConfig, kind: str, dtype=jnp.bfloat16) -> Dict[str, Any]:
    s: Dict[str, Any] = {}
    s.update(_norm_specs(cfg, "norm1"))
    if kind in ("attn", "attn_moe", "local_attn", "decoder"):
        s["attn"] = attn.attention_specs(cfg, dtype)
    elif kind == "ssm":
        s["ssm"] = ssm_mod.ssm_specs(cfg, dtype)
        return s  # mamba2 block has no separate MLP
    elif kind == "rglru":
        s["rglru"] = rglru_mod.rglru_specs(cfg, dtype)
    if kind == "decoder":
        s.update(_norm_specs(cfg, "norm_cross"))
        s["cross"] = attn.cross_attention_specs(cfg, dtype)
    s.update(_norm_specs(cfg, "norm2"))
    if kind == "attn_moe":
        s["moe"] = moe_mod.moe_specs(cfg, dtype)
    else:
        s["mlp"] = mlp_specs(cfg.d_model, cfg.d_ff, dtype)
    return s


# ---------------------------------------------------------------------- apply
def layer_apply(p, x: jax.Array, cfg: ModelConfig, kind: str,
                positions: jax.Array, mode: str,
                cache: Optional[Dict], pos: Optional[jax.Array],
                attn_impl: str, enc_out=None, unroll_chunks: bool = False,
                moe_chunks: int = 1,
                ) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """One block. Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Optional[Dict] = None
    window = cfg.sliding_window
    if kind == "local_attn":
        window = cfg.hybrid.local_window

    h = _norm(p, x, cfg, "norm1")
    if kind in ("attn", "attn_moe", "local_attn", "decoder"):
        self_cache = cache["self"] if (cache is not None and "self" in cache) else cache
        if mode == "train":
            y = attn.self_attention(p["attn"], h, cfg, positions, causal=True,
                                    impl=attn_impl, window=window)
        elif mode == "prefill":
            y, new_self = attn.prefill_attention(p["attn"], h, cfg, positions,
                                                 self_cache, impl=attn_impl,
                                                 window=window)
            new_cache = {"self": new_self} if kind == "decoder" else new_self
        else:  # decode
            y, new_self = attn.decode_attention(p["attn"], h, cfg, self_cache,
                                                pos, window=window)
            new_cache = {"self": new_self} if kind == "decoder" else new_self
    elif kind == "ssm":
        if mode == "train":
            y = ssm_mod.ssm_apply(p["ssm"], h, cfg, unroll_chunks=unroll_chunks)
        elif mode == "prefill":
            y, new_cache = _ssm_prefill(p["ssm"], h, cfg, unroll_chunks)
        else:
            y, new_cache = ssm_mod.ssm_decode_step(p["ssm"], h, cfg, cache)
    elif kind == "rglru":
        if mode == "train":
            y = rglru_mod.rglru_block(p["rglru"], h, cfg)
        elif mode == "prefill":
            y, new_cache = _rglru_prefill(p["rglru"], h, cfg)
        else:
            y, new_cache = rglru_mod.rglru_decode_step(p["rglru"], h, cfg, cache)
    else:
        raise ValueError(kind)
    x = x + y

    if kind == "decoder":
        h = _norm(p, x, cfg, "norm_cross")
        if mode == "decode":
            kv = (cache["cross_k"], cache["cross_v"])
        else:
            kv = attn.encode_cross_kv(p["cross"], enc_out, cfg)
        x = x + attn.cross_attention(p["cross"], h, kv, cfg)
        if new_cache is not None:
            new_cache["cross_k"], new_cache["cross_v"] = kv

    if kind == "ssm":
        return x, new_cache, aux

    h = _norm(p, x, cfg, "norm2")
    if kind == "attn_moe":
        y, aux = moe_mod.moe_apply(p["moe"], h, cfg, a2a_chunks=moe_chunks)
    else:
        y = mlp_apply(p["mlp"], h)
    x = x + y
    x = with_logical(x, ("batch", "seq", None) if mode != "decode"
                     else ("batch", None, None))
    return x, new_cache, aux


def _ssm_prefill(p, h, cfg, unroll_chunks):
    """Full-sequence SSM output + final states for the decode hand-off."""
    s = cfg.ssm
    b, l, d = h.shape
    z, x, B, C, dt, A = ssm_mod._project(p, h, cfg)
    xc = ssm_mod._causal_depthwise_conv(x, p["conv_x"])
    Bc = ssm_mod._causal_depthwise_conv(B, p["conv_B"])
    Cc = ssm_mod._causal_depthwise_conv(C, p["conv_C"])
    nh = s.num_heads(d)
    xh = xc.reshape(b, l, nh, s.head_dim)
    from repro.kernels.ssd_scan import ops as ssd_ops

    y, final = ssd_ops.ssd(xh, dt, A, Bc, Cc, min(s.chunk_size, l),
                           unroll_chunks=unroll_chunks)
    y = y + xh * p["D"][:, None].astype(xh.dtype)
    y = y.reshape(b, l, -1)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["wo"]
    k = s.conv_kernel
    cache = {"state": final, "conv_x": x[:, -(k - 1):],
             "conv_B": B[:, -(k - 1):], "conv_C": C[:, -(k - 1):]}
    return out, cache


def _rglru_prefill(p, h, cfg):
    gate = jax.nn.gelu(h @ p["w_gate"])
    u = h @ p["w_in"]
    k = cfg.hybrid.conv_kernel
    uc = rglru_mod._conv1d(u, p["conv"])
    a, b = rglru_mod._gates(p, uc)
    from repro.kernels.lru_scan import ops as lru_ops

    hseq, h_last = lru_ops.lru_scan(a, b)
    y = gate.astype(jnp.float32) * hseq.astype(jnp.float32)
    out = y.astype(h.dtype) @ p["w_out"]
    return out, {"h": h_last, "conv": u[:, -(k - 1):]}


# ----------------------------------------------------------------- the stacks
def stack_specs(cfg: ModelConfig, scan: bool, dtype=jnp.bfloat16,
                depth0: int = 1) -> Any:
    """Specs for the main stack, layer-provenance tagged: unrolled layer i is
    forward depth ``depth0 + i``; a scanned stack is ONE stacked subtree at
    ``depth0`` (its gradient materializes whole out of the scan backward, so
    there is no finer-grained release to order)."""
    import dataclasses

    from repro.models.layers import tag_layer

    kinds = block_kinds(cfg)
    if scan and uniform_stack(cfg):
        one = layer_specs(cfg, kinds[0], dtype)

        def add_dim(spec: ParamSpec) -> ParamSpec:
            return dataclasses.replace(
                spec, shape=(cfg.num_layers,) + spec.shape,
                axes=("layers",) + spec.axes)

        return tag_layer(jax.tree.map(
            add_dim, one, is_leaf=lambda s: isinstance(s, ParamSpec)), depth0)
    return [tag_layer(layer_specs(cfg, k, dtype), depth0 + i)
            for i, k in enumerate(kinds)]


def stack_apply(params, x, cfg: ModelConfig, positions, mode: str,
                caches, pos, attn_impl: str, remat: str = "none",
                enc_out=None, unroll_chunks: bool = False,
                moe_chunks: int = 1, stream=None):
    """Run the full stack. `params` matches stack_specs' layout (stacked tree
    for scan, list for unrolled). Returns (x, new_caches, aux_total).

    `stream` is the streaming-ZeRO-3 hook: a callable ``(i, p_l) -> params``
    that materializes layer `i`'s parameters (all-gather of its shard-resident
    bucket) INSIDE the layer's remat region, so the gather is emitted just
    before the consuming compute, the gathered buffer dies after the layer's
    forward, and the backward's rematerialization regathers it in reverse
    layer order. Unrolled stacks pass each layer's flat shard dict as `p_l`
    with its index `i`; the scanned lowering uses the scan-carried gather —
    `p_l` is the body's per-layer slice of the (sharded) stacked tree and `i`
    is None. Streaming forces remat in train mode (without it every gathered
    buffer would survive to the backward and there is no memory win)."""
    kinds = block_kinds(cfg)
    scanned = not isinstance(params, list)

    def wrap(f):
        if remat == "dots" and mode == "train":
            return jax.checkpoint(
                f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
        if (remat == "full" or stream is not None) and mode == "train":
            return jax.checkpoint(f)
        return f

    if scanned:
        kind = kinds[0]

        def f(p_l, xc, cache_l):
            if stream is not None:
                p_l = stream(None, p_l)
            return layer_apply(p_l, xc, cfg, kind, positions, mode, cache_l,
                               pos, attn_impl, enc_out, unroll_chunks,
                               moe_chunks=moe_chunks)

        fw = wrap(f)

        if caches is None:
            def body(carry, p_l):
                xc, aux = carry
                xc, _, aux_l = fw(p_l, xc, None)
                return (xc, aux + aux_l), None

            (x, aux), _ = lax.scan(body, (x, jnp.zeros((), jnp.float32)), params)
            return x, None, aux

        def body(carry, xs):
            xc, aux = carry
            p_l, cache_l = xs
            xc, new_cache, aux_l = fw(p_l, xc, cache_l)
            return (xc, aux + aux_l), new_cache

        (x, aux), new_caches = lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params, caches))
        return x, new_caches, aux

    # unrolled
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = []
    for i, (p_l, kind) in enumerate(zip(params, kinds)):
        cache_l = None if caches is None else caches[i]

        def f(pp, xx, cc, kk=kind, ii=i):
            if stream is not None:
                pp = stream(ii, pp)
            return layer_apply(pp, xx, cfg, kk, positions, mode, cc, pos,
                               attn_impl, enc_out, unroll_chunks,
                               moe_chunks=moe_chunks)

        x, new_cache, aux_l = wrap(f)(p_l, x, cache_l)
        aux_total = aux_total + aux_l
        new_caches.append(new_cache)
    if mode == "train":
        new_caches = None
    return x, new_caches, aux_total


# ------------------------------------------------------------- cache builders
def stack_cache_specs(cfg: ModelConfig, batch: int, max_len: int, scan: bool,
                      dtype=jnp.bfloat16):
    """ParamSpec tree for the per-layer decode caches (dry-run inputs)."""
    kinds = block_kinds(cfg)

    def one(kind: str):
        if kind in ("attn", "attn_moe", "local_attn", "decoder"):
            w = max_len
            if kind == "local_attn":
                w = min(max_len, cfg.hybrid.local_window)
            elif cfg.sliding_window is not None:
                w = min(max_len, cfg.sliding_window)
            c = attn.cache_specs(cfg, batch, w, dtype)
            if kind == "decoder":
                hd = cfg.resolved_head_dim
                enc_seq = cfg.encdec.enc_seq
                return {
                    "self": c,
                    "cross_k": ParamSpec((batch, enc_seq, cfg.num_kv_heads, hd),
                                         ("batch", None, "act_kv_heads", None),
                                         dtype, "zeros"),
                    "cross_v": ParamSpec((batch, enc_seq, cfg.num_kv_heads, hd),
                                         ("batch", None, "act_kv_heads", None),
                                         dtype, "zeros"),
                }
            return c
        if kind == "ssm":
            return ssm_mod.ssm_cache_specs(cfg, batch, dtype)
        if kind == "rglru":
            return rglru_mod.rglru_cache_specs(cfg, batch, dtype)
        raise ValueError(kind)

    if scan and uniform_stack(cfg):
        base = one(kinds[0])

        def add_dim(spec: ParamSpec) -> ParamSpec:
            return ParamSpec((cfg.num_layers,) + spec.shape,
                             ("layers",) + spec.axes, spec.dtype, "zeros")

        return jax.tree.map(add_dim, base,
                            is_leaf=lambda s: isinstance(s, ParamSpec))
    return [one(k) for k in kinds]
