"""Attention: GQA (+qk-norm, +sliding window), train/prefill/decode paths.

Implementations (``impl``):
  dense      -- full-score einsum attention (oracle; decode path; small shapes)
  blockwise  -- lax.scan over query chunks, memory-bounded (runnable lowering
                for long prefill; XLA buffer-reuses one chunk of scores)
  blockwise_unrolled -- python-loop chunks (analysis lowering: FLOPs of every
                chunk visible to cost_analysis; scan bodies are counted once)
  flash      -- Pallas TPU kernel (repro.kernels.flash_attention); interpret
                mode on CPU tests

All paths share the projection/rope/mask logic, so implementations are
interchangeable and cross-checked in tests.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import compat  # noqa: F401  (jax version shims)
from repro.config.base import ModelConfig
from repro.models.layers import ParamSpec, apply_rope, rms_norm
from repro.sharding.rules import with_logical

Cache = Dict[str, jax.Array]


# ---------------------------------------------------------------------- specs
def attention_specs(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    hd = cfg.resolved_head_dim
    s: Dict[str, ParamSpec] = {
        "wq": ParamSpec((cfg.d_model, cfg.num_heads, hd), ("embed", "heads", "head_dim"), dtype),
        "wk": ParamSpec((cfg.d_model, cfg.num_kv_heads, hd),
                        ("embed", "kv_heads", "head_dim"), dtype),
        "wv": ParamSpec((cfg.d_model, cfg.num_kv_heads, hd),
                        ("embed", "kv_heads", "head_dim"), dtype),
        "wo": ParamSpec((cfg.num_heads, hd, cfg.d_model), ("heads", "head_dim", "embed"), dtype),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((hd,), (None,), jnp.float32, "ones")
        s["k_norm"] = ParamSpec((hd,), (None,), jnp.float32, "ones")
    return s


# ---------------------------------------------------------------- projections
def project_q(p, x, cfg: ModelConfig, positions) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    return with_logical(q, ("batch", None, "act_heads", None))


def project_kv(p, x, cfg: ModelConfig, positions) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    k = apply_rope(k, positions, cfg.rope_theta)
    k = with_logical(k, ("batch", None, "act_kv_heads", None))
    v = with_logical(v, ("batch", None, "act_kv_heads", None))
    return k, v


# ------------------------------------------------------------------ core sdpa
def _mask(q_pos, k_pos, causal: bool, window: Optional[int]) -> jax.Array:
    """(..., q, k) boolean mask. window counts the current token (SWA)."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], k_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m


def _sdpa_dense(q, k, v, q_pos, k_pos, causal, window, kv_valid=None) -> jax.Array:
    """q: (b,sq,hq,d); k,v: (b,sk,hkv,d). GQA via kv broadcast to full heads.

    Scores stay (b, hq, sq, sk) so the head dim is shardable over the TP axis
    even when hkv < mesh model size (the grouped (hkv, g, ...) layout forced
    score replication + involuntary SPMD remats — measured in the dry-run)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    scores = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    # under tp_sp rules heads own the model axis (seq falls through to None);
    # under dp_sp rules heads replicate and the q-row dim carries it instead
    scores = with_logical(scores, ("batch", "act_heads", "seq", None))
    m = _mask(q_pos, k_pos, causal, window)[:, None]              # (b,1,sq,sk)
    if kv_valid is not None:
        m &= kv_valid[:, None, None, :]
    scores = jnp.where(m, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", probs, v.astype(jnp.float32))
    out = with_logical(out, ("batch", "seq", "act_heads", None))
    return out.astype(q.dtype)


def _sdpa_blockwise(q, k, v, q_pos, k_pos, causal, window, chunk: int,
                    unrolled: bool) -> jax.Array:
    b, sq, hq, d = q.shape
    chunk = min(chunk, sq)
    if sq % chunk != 0:
        return _sdpa_dense(q, k, v, q_pos, k_pos, causal, window)
    n = sq // chunk

    def one(i):
        qs = lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
        qp = lax.dynamic_slice_in_dim(q_pos, i * chunk, chunk, axis=-1)
        return _sdpa_dense(qs, k, v, qp, k_pos, causal, window)

    if unrolled:
        outs = [one(i) for i in range(n)]
        return jnp.concatenate(outs, axis=1)
    ys = lax.map(lambda i: one(i), jnp.arange(n))
    return jnp.moveaxis(ys, 0, 1).reshape(b, sq, hq, d)


def sdpa(q, k, v, q_pos, k_pos, causal=True, window=None, impl="dense",
         chunk: int = 1024, kv_valid=None) -> jax.Array:
    if impl == "dense":
        return _sdpa_dense(q, k, v, q_pos, k_pos, causal, window, kv_valid)
    if impl == "blockwise":
        return _sdpa_blockwise(q, k, v, q_pos, k_pos, causal, window, chunk, False)
    if impl == "blockwise_unrolled":
        return _sdpa_blockwise(q, k, v, q_pos, k_pos, causal, window, chunk, True)
    if impl == "flash":
        from repro.kernels.flash_attention import ops as flash_ops

        return flash_ops.flash_attention(q, k, v, causal=causal, window=window,
                                         q_offset=q_pos, k_offset=k_pos)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------- full blocks
def self_attention(p, x, cfg: ModelConfig, positions, causal=True,
                   impl="dense", window=None) -> jax.Array:
    """Train/prefill self-attention over the full sequence."""
    q = project_q(p, x, cfg, positions)
    k, v = project_kv(p, x, cfg, positions)
    out = sdpa(q, k, v, positions, positions, causal=causal,
               window=window, impl=impl)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return with_logical(y, ("batch", "seq", None))


def make_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Cache:
    """Ring-buffer KV cache. For SWA archs max_len may be min(seq, window)."""
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.num_kv_heads, hd), dtype),
        # absolute position stored in each ring slot (-1 = empty)
        "pos": jnp.full((max_len,), -1, jnp.int32),
    }


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    return {
        "k": ParamSpec((batch, max_len, cfg.num_kv_heads, hd),
                       ("batch", "kv_seq", "act_kv_heads", None), dtype, "zeros"),
        "v": ParamSpec((batch, max_len, cfg.num_kv_heads, hd),
                       ("batch", "kv_seq", "act_kv_heads", None), dtype, "zeros"),
        "pos": ParamSpec((max_len,), ("kv_seq",), jnp.int32, "zeros"),
    }


def prefill_attention(p, x, cfg: ModelConfig, positions, cache: Cache,
                      impl="dense", window=None) -> Tuple[jax.Array, Cache]:
    """Full-sequence attention that also fills the cache (assumes seq fits the
    ring; launcher sizes caches accordingly)."""
    q = project_q(p, x, cfg, positions)
    k, v = project_kv(p, x, cfg, positions)
    out = sdpa(q, k, v, positions, positions, causal=True, window=window, impl=impl)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = with_logical(y, ("batch", "seq", None))

    w = cache["k"].shape[1]
    s = k.shape[1]
    if s >= w:  # keep the last w entries, placed at their ring slots
        ks, vs = k[:, -w:], v[:, -w:]
        ps = positions[0, -w:] if positions.ndim > 1 else positions[-w:]
        # decode writes position p at slot p % w — prefill must agree, else
        # the next eviction removes the wrong token (caught by
        # test_prefill_decode_matches_full_forward[recurrentgemma-2b])
        slots = ps.astype(jnp.int32) % w
        new = {
            "k": jnp.zeros_like(cache["k"]).at[:, slots].set(
                ks.astype(cache["k"].dtype)),
            "v": jnp.zeros_like(cache["v"]).at[:, slots].set(
                vs.astype(cache["v"].dtype)),
            "pos": jnp.full((w,), -1, jnp.int32).at[slots].set(
                ps.astype(jnp.int32)),
        }
    else:
        pos1 = positions[0] if positions.ndim > 1 else positions
        new = {
            "k": lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), 0, 1),
            "v": lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), 0, 1),
            "pos": lax.dynamic_update_slice_in_dim(
                cache["pos"], pos1.astype(jnp.int32), 0, 0),
        }
    return y, new


def decode_attention(p, x, cfg: ModelConfig, cache: Cache, pos: jax.Array,
                     window=None) -> Tuple[jax.Array, Cache]:
    """One-token step against the ring cache. `pos` is a scalar int32 (same
    position for every sequence in the batch — the wave scheduler) or a
    per-slot (b,) vector (continuous batching: every slot decodes at its own
    position; the cache then carries a per-slot ``pos`` of shape (b, w)).

    Under a multi-chip sharding context the scalar-pos path dispatches to the
    shard_map flash-decode: the KV domain stays sequence-sharded, each chip
    computes a partial softmax over its subdomain and the results combine
    hierarchically (max + scaled sums) — the HDOT task-reduction pattern.
    Without it, GSPMD all-gathers the whole cache every token (measured
    1.02 GB/chip/layer for granite decode_32k — EXPERIMENTS §Perf cell C).
    The per-slot path is TP-sharded explicitly by models/decode_tp instead."""
    b = x.shape[0]
    per_slot = jnp.ndim(pos) == 1
    positions = pos[:, None] if per_slot else jnp.broadcast_to(pos, (b, 1))
    q = project_q(p, x, cfg, positions)
    k, v = project_kv(p, x, cfg, positions)

    w = cache["k"].shape[1]
    from repro.sharding.rules import current_context, resolve_pspec

    ctx = current_context()
    kv_axes: Tuple[str, ...] = ()
    if ctx is not None:
        spec = resolve_pspec(cache["k"].shape,
                             ("batch", "kv_seq", "act_kv_heads", None), ctx)
        entry = spec[1] if len(spec) > 1 else None
        if entry is not None:
            kv_axes = entry if isinstance(entry, tuple) else (entry,)
    n_shards = 1
    for a in kv_axes:
        n_shards *= ctx.axis_size(a)
    if kv_axes and n_shards > 1 and w % n_shards == 0 and not per_slot:
        out, new_cache = _flash_decode_sharded(q, k, v, cache, pos, window,
                                               ctx, kv_axes)
    else:
        out, new_cache = _decode_dense(q, k, v, cache, pos, window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = with_logical(y, ("batch", None, None))
    return y, new_cache


def _decode_dense(q, k, v, cache: Cache, pos, window) -> Tuple[jax.Array, Cache]:
    """Single-device reference decode path (also the oracle for the sharded
    flash-decode in tests). Scalar `pos` updates one shared ring slot; a
    per-slot (b,) `pos` scatters row-wise into a per-slot (b, w) ring."""
    b = q.shape[0]
    w = cache["k"].shape[1]
    if jnp.ndim(pos) == 1:
        # continuous batching: each slot writes its own ring position
        positions = pos[:, None]
        slot = (pos % w).astype(jnp.int32)
        rows = jnp.arange(b)
        ck = cache["k"].at[rows, slot].set(k[:, 0].astype(cache["k"].dtype))
        cv = cache["v"].at[rows, slot].set(v[:, 0].astype(cache["v"].dtype))
        cpos = cache["pos"].at[rows, slot].set(pos.astype(jnp.int32))
        k_pos = cpos                                            # (b, w)
        kv_valid = cpos >= 0
    else:
        positions = jnp.broadcast_to(pos, (b, 1))
        slot = pos % w
        ck = lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, 1)
        cv = lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, 1)
        cpos = lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.reshape(pos, (1,)).astype(jnp.int32), slot, 0)
        k_pos = jnp.broadcast_to(cpos, (b, w))
        kv_valid = jnp.broadcast_to(cpos >= 0, (b, w))
    out = _sdpa_dense(q, ck, cv, positions, k_pos, causal=True, window=window,
                      kv_valid=kv_valid)
    return out, {"k": ck, "v": cv, "pos": cpos}


def _flash_decode_sharded(q, k, v, cache: Cache, pos, window,
                          ctx, kv_axes: Tuple[str, ...] = ("model",)
                          ) -> Tuple[jax.Array, Cache]:
    """shard_map flash-decode over the seq-sharded ring cache.

    Per chip: local DUS (the writing chip is the slot owner), local partial
    softmax (m, sum exp, weighted V), then pmax/psum combine over `kv_axes`
    — per-layer wire is O(b*h*hd) instead of O(b*S*kv*hd)."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import resolve_pspec

    mesh = ctx.mesh
    axis = kv_axes if len(kv_axes) > 1 else kv_axes[0]
    n_shards = 1
    for a in kv_axes:
        n_shards *= ctx.axis_size(a)
    b, _, hq, hd = q.shape
    w = cache["k"].shape[1]
    chunk = w // n_shards
    hkv = cache["k"].shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)

    batch_spec = resolve_pspec((b,), ("batch",), ctx)
    bax = batch_spec[0] if len(batch_spec) else None
    if isinstance(bax, tuple):  # drop axes the cache seq dim already uses
        bax = tuple(a for a in bax if a not in kv_axes) or None
    elif bax in kv_axes:
        bax = None

    def body(q, k_new, v_new, ck, cv, cpos, pos):
        # ck/cv: (b_loc, chunk, hkv, hd); cpos: (chunk,)
        idx = lax.axis_index(axis)
        slot = pos % w
        owner = slot // chunk == idx
        local_slot = jnp.where(owner, slot % chunk, 0)
        ck = jnp.where(
            owner,
            lax.dynamic_update_slice_in_dim(ck, k_new.astype(ck.dtype),
                                            local_slot, 1), ck)
        cv = jnp.where(
            owner,
            lax.dynamic_update_slice_in_dim(cv, v_new.astype(cv.dtype),
                                            local_slot, 1), cv)
        cpos = jnp.where(
            owner,
            lax.dynamic_update_slice_in_dim(
                cpos, jnp.reshape(pos, (1,)).astype(jnp.int32), local_slot, 0),
            cpos)

        kk = jnp.repeat(ck, g, axis=2) if g > 1 else ck      # (b,chunk,hq,hd)
        vv = jnp.repeat(cv, g, axis=2) if g > 1 else cv
        s = jnp.einsum("bqhd,bthd->bhqt", q.astype(jnp.float32),
                       kk.astype(jnp.float32)) * scale        # (b,h,1,chunk)
        valid = (cpos >= 0) & (cpos <= pos)
        if window is not None:
            valid &= cpos > pos - window
        s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
        m_loc = jnp.max(s, axis=-1, keepdims=True)            # (b,h,1,1)
        m_glob = lax.pmax(m_loc, axis)
        # all-masked shards: exp(-inf - finite) = 0 contribution
        p_ = jnp.exp(s - m_glob)
        p_ = jnp.where(valid[None, None, None, :], p_, 0.0)
        den = lax.psum(jnp.sum(p_, axis=-1), axis)            # (b,h,1)
        num = lax.psum(jnp.einsum("bhqt,bthd->bqhd", p_,
                                  vv.astype(jnp.float32)), axis)
        out = num / jnp.maximum(den, 1e-30)[:, :, :, None].swapaxes(1, 2)
        return out.astype(q.dtype), ck, cv, cpos

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(bax), P(bax), P(bax), P(bax, axis), P(bax, axis),
                  P(axis), P()),
        out_specs=(P(bax), P(bax, axis), P(bax, axis), P(axis)))
    out, ck, cv, cpos = fn(q, k, v, cache["k"], cache["v"], cache["pos"], pos)
    return out, {"k": ck, "v": cv, "pos": cpos}


# ------------------------------------------------------------ cross-attention
def cross_attention_specs(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    return attention_specs(cfg, dtype)


def cross_attention(p, x, enc_kv: Tuple[jax.Array, jax.Array], cfg: ModelConfig) -> jax.Array:
    """Decoder->encoder attention; enc k/v precomputed once at prefill."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])   # no rope on cross-attn
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = enc_kv
    t = k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(t), (b, t))
    out = _sdpa_dense(q, k, v, positions, k_pos, causal=False, window=None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode_cross_kv(p, enc_out: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v
