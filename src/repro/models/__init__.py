from repro.models.model import (
    LanguageModel,
    abstract_params,
    build_model,
    init_params,
    input_specs,
)

__all__ = [
    "LanguageModel",
    "abstract_params",
    "build_model",
    "init_params",
    "input_specs",
]
