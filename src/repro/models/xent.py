"""Fused linear + cross-entropy with a custom VJP (§Perf cell A, iteration 4).

The naive tail  logits = x @ W; loss = -mean(log_softmax[targets])  autodiffs
into (a) a saved (b, s, V) f32 log-probability residual, (b) a scatter-add for
d(take_along_axis) that GSPMD lowers to full-tensor all-reduces (measured
16.8 GB/chip per all-reduce for llama3-405b train_4k), and (c) f32 dW/dx
einsums.

This op instead:
  fwd: logits in f32 (stability), loss from logsumexp + gathered target
       logit; saves only (x, w, targets, lse) — the (b,s,V) tensor is NOT a
       residual.
  bwd: recomputes logits once, forms  dlogits = (softmax - onehot) * g / N
       ELEMENTWISE (iota == targets comparison — no scatter), casts to bf16
       (dlogits is in [-1, 1]; standard production practice), and constrains
       dx / dW to the activation/parameter shardings so the partials
       reduce-scatter instead of all-reducing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import with_logical


@jax.custom_vjp
def linear_xent(x: jax.Array, w: jax.Array, targets: jax.Array) -> jax.Array:
    """x: (b, s, d) activations; w: (d, V); targets: (b, s) int32.
    Returns mean cross-entropy over all positions."""
    loss, _ = _fwd(x, w, targets)
    return loss


def _logits(x, w):
    return jnp.einsum("bsd,dv->bsv", x, w,
                      preferred_element_type=jnp.float32)


def _fwd(x, w, targets):
    logits = _logits(x, w)
    logits = with_logical(logits, ("batch", "seq", "vocab"))
    lse = jax.scipy.special.logsumexp(logits, axis=-1)            # (b, s)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    return loss, (x, w, targets, lse)


def _bwd(res, g):
    x, w, targets, lse = res
    b, s = targets.shape
    n = b * s
    logits = _logits(x, w)                                        # recompute
    logits = with_logical(logits, ("batch", "seq", "vocab"))
    p = jnp.exp(logits - lse[..., None])
    iota = jax.lax.broadcasted_iota(jnp.int32, p.shape, 2)
    dlogits = jnp.where(iota == targets[..., None], p - 1.0, p)
    dlogits = (dlogits * (g / n)).astype(x.dtype)                 # bf16 cotangent
    dlogits = with_logical(dlogits, ("batch", "seq", "vocab"))
    dx = jnp.einsum("bsv,dv->bsd", dlogits, w)
    dx = with_logical(dx, ("batch", "seq", None))
    dw = jnp.einsum("bsd,bsv->dv", x, dlogits)
    dw = with_logical(dw.astype(w.dtype), ("embed", "vocab"))
    return dx, dw, None


linear_xent.defvjp(_fwd, _bwd)


def xent_ref(x, w, targets):
    """Naive reference (the old train_loss tail) — test oracle."""
    logits = jnp.einsum("bsd,dv->bsv", x, w).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
