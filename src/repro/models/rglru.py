"""Griffin/RecurrentGemma recurrent block: conv1d -> RG-LRU, gated
[arXiv:2402.19427].

    r_t = sigmoid(x_t Wr + br)            (recurrence gate)
    i_t = sigmoid(x_t Wi + bi)            (input gate)
    a_t = exp(-c * softplus(L) * r_t)     (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The linear recurrence runs through kernels/lru_scan (associative-scan oracle /
Pallas chunked kernel). lru_width rides the TP axis.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.kernels.lru_scan import ops as lru_ops
from repro.models.layers import ParamSpec
from repro.sharding.rules import with_logical

_C = 8.0


def rglru_specs(cfg: ModelConfig, dtype=jnp.bfloat16) -> Dict[str, ParamSpec]:
    hb = cfg.hybrid
    assert hb is not None
    d = cfg.d_model
    w = hb.lru_width or d
    k = hb.conv_kernel
    return {
        "w_gate": ParamSpec((d, w), ("embed", "lru"), dtype),
        "w_in": ParamSpec((d, w), ("embed", "lru"), dtype),
        "conv": ParamSpec((k, w), ("conv", "lru"), dtype),
        "wr": ParamSpec((w, w), ("lru", None), dtype),
        "br": ParamSpec((w,), (None,), jnp.float32, "zeros"),
        "wi": ParamSpec((w, w), ("lru", None), dtype),
        "bi": ParamSpec((w,), (None,), jnp.float32, "zeros"),
        "a_log": ParamSpec((w,), (None,), jnp.float32, "zeros"),
        "w_out": ParamSpec((w, d), ("lru", "embed"), dtype),
    }


def _gates(p, x: jax.Array):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wr"].astype(jnp.float32) + p["br"])
    i = jax.nn.sigmoid(xf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -_C * jax.nn.softplus(p["a_log"]) * r          # (b,l,w) log decay
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xf)
    return a, gated


def _conv1d(x: jax.Array, w: jax.Array, state=None):
    k = w.shape[0]
    pad = (jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1]] * w[j]
    return out


def rglru_block(p, x: jax.Array, cfg: ModelConfig, impl: str = "auto") -> jax.Array:
    """Full-sequence Griffin recurrent block. x: (b, l, d)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    u = with_logical(u, ("batch", None, "lru"))
    u = _conv1d(u, p["conv"])
    a, b = _gates(p, u)
    h, _ = lru_ops.lru_scan(a, b, impl=impl)
    y = gate.astype(jnp.float32) * h.astype(jnp.float32)
    return (y.astype(x.dtype)) @ p["w_out"]


def rglru_cache_specs(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    hb = cfg.hybrid
    w = hb.lru_width or cfg.d_model
    k = hb.conv_kernel
    return {
        "h": ParamSpec((batch, w), ("batch", "lru"), jnp.float32, "zeros"),
        "conv": ParamSpec((batch, k - 1, w), ("batch", None, "lru"), dtype, "zeros"),
    }


def rglru_decode_step(p, x: jax.Array, cfg: ModelConfig,
                      cache: Dict) -> Tuple[jax.Array, Dict]:
    """x: (b, 1, d)."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    new_conv = jnp.concatenate([cache["conv"].astype(u.dtype), u], axis=1)[:, 1:]
    u = _conv1d(u, p["conv"], state=cache["conv"])
    a, b = _gates(p, u)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = gate[:, 0].astype(jnp.float32) * h
    out = (y.astype(x.dtype) @ p["w_out"])[:, None, :]
    return out, {"h": h, "conv": new_conv}
