"""LanguageModel: the public model API used by trainer / server / dry-run.

Entry points per shape kind:
  train_loss(params, batch)              -- batch: tokens/targets (+ frontend stubs)
  prefill(params, batch)                 -- returns (logits_last, caches)
  decode_step(params, token, caches, pos)-- one token against the caches

`input_specs` produces ShapeDtypeStructs (+ logical axes) for every entry
point so the multi-pod dry-run lowers without allocating anything.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.config.shapes import ShapeConfig
from repro.models import transformer as tfm
from repro.models.layers import (
    ParamSpec,
    abstract_from_specs,
    axes_from_specs,
    init_from_specs,
    layer_norm,
    layers_from_specs,
    sinusoidal_embedding,
    tag_layer,
)
from repro.sharding.rules import with_logical

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelOptions:
    attn_impl: str = "dense"          # dense | blockwise | blockwise_unrolled | flash
    attn_chunk: int = 1024
    scan_layers: bool = True
    remat: str = "none"
    unroll_chunks: bool = False       # SSD chunk loop unrolled (analysis lowering)
    # fused linear+cross-entropy custom-VJP (models/xent.py). Targets the
    # GSPMD/jit path; under shard_map manual axes custom_vjp cotangent
    # varying-axes checks reject it -> manual-mode callers set False.
    fused_xent: bool = True
    # MoE expert-parallel a2a over-decomposition degree Q (core.a2a_scan):
    # dispatch/combine chunked into Q capacity slices so slice k+1's a2a
    # overlaps slice k's expert FFN. 1 = monolithic (today's schedule).
    moe_a2a_chunks: int = 1
    dtype: Any = jnp.bfloat16


class LanguageModel:
    def __init__(self, cfg: ModelConfig, options: Optional[ModelOptions] = None):
        self.cfg = cfg
        self.opt = options or ModelOptions()

    # ------------------------------------------------------------------ specs
    def param_specs(self) -> PyTree:
        """Every leaf carries layer provenance (``ParamSpec.layer``): forward
        depth 0 for the embedding/frontends, ``1..N`` through the stacks, and
        the deepest tag on the head — so the grad-sync scheduler knows which
        leaves' gradients complete first in the backward pass."""
        cfg, dt = self.cfg, self.opt.dtype
        # encoder backward runs AFTER the decoder stack's (its grads gather
        # cross-attention contributions from every decoder layer), so the
        # encoder occupies depths 1..enc_layers below the main stack
        enc_depth = cfg.encdec.enc_layers + 1 if cfg.family == "encdec" else 0
        stack0 = enc_depth + 1
        head_depth = stack0 + cfg.num_layers
        specs: Dict[str, Any] = {
            "embed": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                               dt, scale=cfg.d_model ** -0.5, layer=0),
            "layers": tfm.stack_specs(cfg, self.opt.scan_layers, dt,
                                      depth0=stack0),
        }
        specs.update(tag_layer(tfm._norm_specs(cfg, "final_norm"), head_depth))
        if not cfg.tie_embeddings:
            specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                         ("embed", "vocab"), dt,
                                         layer=head_depth)
        if cfg.family == "encdec":
            enc_cfg = dataclasses.replace(cfg, num_layers=cfg.encdec.enc_layers)
            self._enc_cfg = enc_cfg
            specs["encoder"] = [tag_layer(tfm.layer_specs(enc_cfg, "attn", dt),
                                          1 + i)
                                for i in range(cfg.encdec.enc_layers)]
            specs.update(tag_layer(tfm._norm_specs(cfg, "enc_norm"), enc_depth))
        if cfg.family == "vlm":
            # stub projection for precomputed patch embeddings (identity-sized)
            specs["vision_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                             ("embed", None), dt, layer=0)
        if cfg.family == "encdec":
            specs["audio_proj"] = ParamSpec((cfg.d_model, cfg.d_model),
                                            ("embed", None), dt, layer=0)
        return specs

    def init(self, rng: jax.Array) -> PyTree:
        return init_from_specs(self.param_specs(), rng)

    def abstract_params(self) -> PyTree:
        return abstract_from_specs(self.param_specs())

    def param_axes(self) -> PyTree:
        return axes_from_specs(self.param_specs())

    def param_layers(self) -> PyTree:
        """Layer-provenance tree matching :meth:`init`'s params: per-leaf
        forward depth, consumed by the reverse-topological grad-sync bucket
        schedule (core.overlap)."""
        return layers_from_specs(self.param_specs())

    # ------------------------------------------------------------- embeddings
    def _embed(self, params, tokens: jax.Array) -> jax.Array:
        x = jnp.take(params["embed"], tokens, axis=0)
        if self.cfg.family == "encdec":
            x = x + sinusoidal_embedding(tokens.shape[1], self.cfg.d_model
                                         ).astype(x.dtype)[None]
        return x * jnp.asarray(self.cfg.d_model ** 0.5, x.dtype)

    def _unembed(self, params, x: jax.Array) -> jax.Array:
        x = tfm._norm(params, x, self.cfg, "final_norm")
        if self.cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
        else:
            logits = x @ params["lm_head"]
        return with_logical(logits.astype(jnp.float32), ("batch", "seq", "vocab"))

    def _encode(self, params, frames: jax.Array) -> jax.Array:
        """Whisper encoder over stub frame embeddings."""
        cfg = self.cfg
        x = (frames @ params["audio_proj"]
             + sinusoidal_embedding(frames.shape[1], cfg.d_model
                                    ).astype(frames.dtype)[None])
        b, t, _ = x.shape
        pos = jnp.broadcast_to(jnp.arange(t), (b, t))
        enc_cfg = dataclasses.replace(cfg, num_layers=cfg.encdec.enc_layers)
        for p_l in params["encoder"]:
            x, _, _ = tfm.layer_apply(p_l, x, enc_cfg, "attn", pos, "train",
                                      None, None, self.opt.attn_impl)
        return layer_norm(x, params["enc_norm"], params["enc_norm_b"], cfg.norm_eps)

    def _prepend_frontend(self, params, x: jax.Array, batch: Dict) -> jax.Array:
        if self.cfg.family == "vlm":
            patches = batch["patches"] @ params["vision_proj"]
            x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        return x

    # ---------------------------------------------------------------- forward
    def _forward(self, params, batch: Dict, mode: str, caches=None,
                 pos=None) -> Tuple[jax.Array, Any, jax.Array]:
        cfg = self.cfg
        tokens = batch["token"] if mode == "decode" else batch["tokens"]
        tokens = with_logical(tokens, ("batch", "seq"))
        x = self._embed(params, tokens)
        if mode != "decode":
            x = self._prepend_frontend(params, x, batch)
        b, s, _ = x.shape
        if mode == "decode":
            # scalar pos: every slot at the same position (wave scheduler);
            # (b,) pos: per-slot positions (continuous batching)
            positions = pos[:, None] if jnp.ndim(pos) == 1 \
                else jnp.broadcast_to(pos, (b, 1))
        else:
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = with_logical(x, ("batch", "seq", None) if mode != "decode"
                         else ("batch", None, None))

        enc_out = None
        if cfg.family == "encdec" and mode != "decode":
            enc_out = self._encode(params, batch["frames"])

        x, new_caches, aux = tfm.stack_apply(
            params["layers"], x, cfg, positions, mode, caches, pos,
            self.opt.attn_impl, remat=self.opt.remat, enc_out=enc_out,
            unroll_chunks=self.opt.unroll_chunks,
            moe_chunks=self.opt.moe_a2a_chunks)
        return x, new_caches, aux

    # ------------------------------------------------------------ entry points
    def train_loss(self, params, batch: Dict) -> jax.Array:
        x, _, aux = self._forward(params, batch, "train")
        if self.cfg.family == "vlm":   # strip patch positions from the loss
            x = x[:, self.cfg.num_vision_patches:]
        targets = batch["targets"]
        if self.opt.fused_xent:
            x = tfm._norm(params, x, self.cfg, "final_norm")
            from repro.models.xent import linear_xent

            w = (params["embed"].T if self.cfg.tie_embeddings
                 else params["lm_head"])
            loss = linear_xent(x, w, targets)
        else:
            logits = self._unembed(params, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
            loss = -jnp.mean(ll)
        return loss + aux.astype(loss.dtype)

    def train_loss_streamed(self, pflat, batch: Dict, stream) -> jax.Array:
        """Streaming-ZeRO-3 train loss: `pflat` holds the per-bucket flat
        parameter SHARDS (inside shard_map over the DP axes) and `stream` is
        the :class:`~repro.core.overlap.FsdpStream` gather/free schedule.

        Each layer all-gathers exactly its own bucket inside its remat
        region: the gather is emitted just before the consuming compute, the
        gathered buffer dies after the layer's forward, and the backward
        rematerializes layers in reverse order — regathering buckets
        last-backward-first, with AD transposing each tiled all-gather into
        the bucket's tiled reduce-scatter. The embed and head buckets gather
        un-checkpointed at their point of use: the take-backward never needs
        the embedding table primal (its transpose is a scatter of the
        cotangent), and the head weight's saved residual spans only the
        forward/backward boundary where it IS the working set — while
        checkpointing them would restructure the softmax backward and break
        bit-identity with the gather-all step. Peak live params ≈ shard + a
        bounded working set, instead of the full tree.

        Gradients w.r.t. `pflat` come back already reduce-scattered (the SUM
        over the DP shards — divide by the shard count for the mean). Uses
        the unfused unembed path (custom-VJP fused xent is rejected under
        shard_map manual axes), like every explicit-schedule caller."""
        cfg = self.cfg
        if self.opt.scan_layers:
            raise ValueError(
                "train_loss_streamed needs the unrolled stack "
                "(scan_layers=False): per-layer gather placement requires "
                "visible layer boundaries; the scanned lowering streams via "
                "stack_apply's scan-carried gather instead")
        if cfg.family == "encdec":
            raise ValueError(
                "train_loss_streamed supports decoder-only stacks (the "
                "encoder's cross-attention KV is consumed by every decoder "
                "layer, so its buckets have no single free point)")
        stack0 = 1
        head_depth = stack0 + cfg.num_layers
        head_depths = (head_depth, 0) if cfg.tie_embeddings else (head_depth,)

        p0 = stream.materialize(pflat, 0)
        tokens = with_logical(batch["tokens"], ("batch", "seq"))
        x = self._embed(p0, tokens)
        x = self._prepend_frontend(p0, x, batch)
        b, s, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = with_logical(x, ("batch", "seq", None))

        def layer_stream(i, flat):
            return stream.materialize(flat, stack0 + i)["layers"][i]

        stack_flat = [stream.flat_at(pflat, stack0 + i)
                      for i in range(cfg.num_layers)]
        x, _, aux = tfm.stack_apply(
            stack_flat, x, cfg, positions, "train", None, None,
            self.opt.attn_impl, remat=self.opt.remat,
            unroll_chunks=self.opt.unroll_chunks,
            moe_chunks=self.opt.moe_a2a_chunks, stream=layer_stream)

        if cfg.family == "vlm":   # strip patch positions from the loss
            x = x[:, cfg.num_vision_patches:]

        ph = stream.materialize(pflat, *head_depths)
        logits = self._unembed(ph, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        targets = batch["targets"]
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        loss = -jnp.mean(ll)
        return loss + aux.astype(loss.dtype)

    def prefill(self, params, batch: Dict,
                max_len: Optional[int] = None) -> Tuple[jax.Array, Any]:
        """`max_len` sizes the ring caches for the decode phase that follows;
        without it the cache holds exactly the prompt and the FIRST generated
        token evicts prompt token 0 (caught by
        test_prefill_decode_matches_full_forward)."""
        caches = self._init_caches_for_prefill(batch, max_len)
        x, new_caches, _ = self._forward(params, batch, "prefill", caches=caches)
        logits = self._unembed(params, x[:, -1:])
        return logits, new_caches

    def decode_step(self, params, token: jax.Array, caches, pos: jax.Array
                    ) -> Tuple[jax.Array, Any]:
        x, new_caches, _ = self._forward(params, {"token": token}, "decode",
                                         caches=caches, pos=pos)
        logits = self._unembed(params, x)
        return logits, new_caches

    # ----------------------------------------------------------------- caches
    def cache_specs(self, batch: int, max_len: int) -> PyTree:
        return tfm.stack_cache_specs(self.cfg, batch, max_len,
                                     self.opt.scan_layers, self.opt.dtype)

    def init_caches(self, batch: int, max_len: int) -> PyTree:
        return init_from_specs(self.cache_specs(batch, max_len),
                               jax.random.PRNGKey(0))

    def _init_caches_for_prefill(self, batch: Dict,
                                 max_len: Optional[int] = None) -> PyTree:
        b, s = batch["tokens"].shape
        if self.cfg.family == "vlm":
            s += self.cfg.num_vision_patches
        return self.init_caches(b, max(s, max_len or 0))


# ------------------------------------------------------------------- factories
def build_model(cfg: ModelConfig, options: Optional[ModelOptions] = None
                ) -> LanguageModel:
    return LanguageModel(cfg, options)


def init_params(cfg: ModelConfig, seed: int = 0,
                options: Optional[ModelOptions] = None) -> PyTree:
    return build_model(cfg, options).init(jax.random.PRNGKey(seed))


def abstract_params(cfg: ModelConfig, options: Optional[ModelOptions] = None
                    ) -> PyTree:
    return build_model(cfg, options).abstract_params()


# ------------------------------------------------------------------ input specs
def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                options: Optional[ModelOptions] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins (+ logical axes) for a dry-run cell.

    train/prefill: {'tokens', 'targets'?, 'patches'?, 'frames'?}
    decode:        {'token', 'caches', 'pos'}
    """
    model = build_model(cfg, options)
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(bb, ss):
        return jax.ShapeDtypeStruct((bb, ss), i32)

    specs: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    if shape.kind == "train":
        s_text = s - (cfg.num_vision_patches if cfg.family == "vlm" else 0)
        specs["tokens"] = tok(b, s_text)
        axes["tokens"] = ("batch", "seq")
        specs["targets"] = tok(b, s_text)
        axes["targets"] = ("batch", "seq")
    elif shape.kind == "prefill":
        s_text = s - (cfg.num_vision_patches if cfg.family == "vlm" else 0)
        specs["tokens"] = tok(b, s_text)
        axes["tokens"] = ("batch", "seq")
    else:  # decode
        specs["token"] = tok(b, 1)
        axes["token"] = ("batch", None)
        cspecs = model.cache_specs(b, s)
        specs["caches"] = abstract_from_specs(cspecs)
        axes["caches"] = axes_from_specs(cspecs)
        specs["pos"] = jax.ShapeDtypeStruct((), i32)
        axes["pos"] = ()
    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            specs["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_vision_patches, cfg.d_model), jnp.bfloat16)
            axes["patches"] = ("batch", None, None)
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encdec.enc_seq, cfg.d_model), jnp.bfloat16)
            axes["frames"] = ("batch", None, None)
    return {"specs": specs, "axes": axes}
