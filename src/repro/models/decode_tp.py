"""TP-sharded continuous-batching decode step on the HDOT collective matmuls.

One decode token per slot is tiny compute over large weights — the classic
latency-critical TP cell. GSPMD would emit two-phase all-gather / psum_scatter
walls around every projection; here the step is an explicit shard_map over a
("data", "model") mesh and every projection/FFN matmul rides
`ag_matmul_hdot` / `matmul_rs_hdot` (core.collective_matmul), so each ring
hop's ppermute travels under the previous chunk's matmul — the paper's
communication-task overlap, structurally checked by the `lm_decode_tp` lint
target (NO-OVERLAP-WINDOW at zero exposed collectives + exact PAIR-COUNT).

Layout per TP rank (Megatron + sequence parallelism over the SLOT dim):
  x_sp (slots_loc/tp, d)  --ag-ring-->  fused QKV (slots_loc, heads_loc)
  GQA attention fully local on the kv-head-sharded slot caches
  out --rs-ring--> x_sp;  same ag/rs pair for the fused gate|up / down MLP;
  one final ag ring into the replicated unembedding = full logits per rank.
Rings per step: 4 * num_layers + 1. The "data" axis is pure slot parallelism
(no cross-data communication at all).

Cache writes use per-row unrolled `lax.dynamic_update_slice` rather than a
vectorized scatter: HLO `scatter` counts as compute for the lint's overlap
windows, DUS does not — the bookkeeping must not be what hides a collective.

`build_decode_step(model, mesh)` returns a drop-in for
`BatchServer(decode_step_fn=...)`; greedy outputs are token-exact against the
single-device oracle (tests/test_decode_tp.py).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat  # noqa: F401  (jax.shard_map on 0.4.x)
from repro.config.base import ModelConfig
from repro.core import collective_matmul as cm
from repro.models.attention import _sdpa_dense
from repro.models.layers import apply_rope, rms_norm
from repro.models.model import LanguageModel

PyTree = Any


def expected_permute_total(cfg: ModelConfig, slots: int, dp: int, tp: int,
                           chunks: Optional[int] = None) -> int:
    """PAIR-COUNT expectation for one decode step: (4L + 1) hdot rings
    (QKV-ag, wo-rs, gate|up-ag, down-rs per layer, plus the unembed ag),
    each `ring_permute_count` ppermutes — derived from the same
    `_ring_pieces` split the runtime unrolls."""
    s_sp = slots // dp // tp
    return (4 * cfg.num_layers + 1) * cm.ring_permute_count(
        s_sp, tp, chunks=chunks)


def build_decode_step(model: LanguageModel, mesh,
                      data_axis: str = "data", model_axis: str = "model",
                      mode: str = "hdot", chunks: Optional[int] = None):
    """Returns step(params, token (b,1), caches, pos (b,)) -> (logits, caches)
    with the BatchServer continuous-decode calling convention (per-slot pos,
    per-slot cache["pos"] rings). `mode="two_phase"` swaps every ring for the
    serial all_gather/psum_scatter reference (the broken lint fixture)."""
    cfg = model.cfg
    if cfg.family not in ("dense",):
        raise ValueError(
            f"TP decode cell supports the dense family, got {cfg.family!r}")
    dp = mesh.shape[data_axis]
    tp = mesh.shape[model_axis]
    hd = cfg.resolved_head_dim
    if cfg.num_heads % tp or cfg.num_kv_heads % tp:
        raise ValueError(
            f"heads ({cfg.num_heads} q / {cfg.num_kv_heads} kv) must divide "
            f"over the {tp}-way {model_axis!r} axis")
    if cfg.d_ff % tp:
        raise ValueError(f"d_ff {cfg.d_ff} must divide over tp={tp}")
    hq_loc = cfg.num_heads // tp
    hkv_loc = cfg.num_kv_heads // tp
    f_loc = cfg.d_ff // tp
    d = cfg.d_model
    scanned = model.opt.scan_layers

    def _layer(pl, x_sp, cache_l, pos, idx):
        b_loc = pos.shape[0]
        ck, cv, cpos = cache_l["k"], cache_l["v"], cache_l["pos"]
        w = ck.shape[1]
        h = rms_norm(x_sp, pl["norm1"], cfg.norm_eps)
        ap = pl["attn"]
        wq = lax.dynamic_slice_in_dim(ap["wq"], idx * hq_loc, hq_loc, 1)
        wk = lax.dynamic_slice_in_dim(ap["wk"], idx * hkv_loc, hkv_loc, 1)
        wv = lax.dynamic_slice_in_dim(ap["wv"], idx * hkv_loc, hkv_loc, 1)
        wqkv = jnp.concatenate([wq.reshape(d, hq_loc * hd),
                                wk.reshape(d, hkv_loc * hd),
                                wv.reshape(d, hkv_loc * hd)], axis=1)
        qkv = cm.ag_matmul(h, wqkv, model_axis, mode, chunks)  # (b_loc, ...)
        q = qkv[:, :hq_loc * hd].reshape(b_loc, 1, hq_loc, hd)
        k = qkv[:, hq_loc * hd:(hq_loc + hkv_loc) * hd
                ].reshape(b_loc, 1, hkv_loc, hd)
        v = qkv[:, (hq_loc + hkv_loc) * hd:].reshape(b_loc, 1, hkv_loc, hd)
        if cfg.qk_norm:
            q = rms_norm(q, ap["q_norm"], cfg.norm_eps)
            k = rms_norm(k, ap["k_norm"], cfg.norm_eps)
        positions = pos[:, None]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        # per-row unrolled ring write (see module docstring: DUS, not scatter)
        for i in range(b_loc):
            sl = pos[i] % w
            ck = lax.dynamic_update_slice(ck, k[i:i + 1].astype(ck.dtype),
                                          (i, sl, 0, 0))
            cv = lax.dynamic_update_slice(cv, v[i:i + 1].astype(cv.dtype),
                                          (i, sl, 0, 0))
            cpos = lax.dynamic_update_slice(cpos, pos[i].reshape(1, 1),
                                            (i, sl))
        out = _sdpa_dense(q, ck, cv, positions, cpos, causal=True,
                          window=cfg.sliding_window, kv_valid=cpos >= 0)
        wo = lax.dynamic_slice_in_dim(ap["wo"], idx * hq_loc, hq_loc, 0)
        x_sp = x_sp + cm.matmul_rs(out.reshape(b_loc, hq_loc * hd),
                                   wo.reshape(hq_loc * hd, d),
                                   model_axis, mode, chunks)
        h2 = rms_norm(x_sp, pl["norm2"], cfg.norm_eps)
        mp = pl["mlp"]
        wg = lax.dynamic_slice_in_dim(mp["gate"], idx * f_loc, f_loc, 1)
        wu = lax.dynamic_slice_in_dim(mp["up"], idx * f_loc, f_loc, 1)
        gu = cm.ag_matmul(h2, jnp.concatenate([wg, wu], axis=1),
                          model_axis, mode, chunks)
        hm = jax.nn.silu(gu[:, :f_loc]) * gu[:, f_loc:]
        wd = lax.dynamic_slice_in_dim(mp["down"], idx * f_loc, f_loc, 0)
        x_sp = x_sp + cm.matmul_rs(hm, wd, model_axis, mode, chunks)
        return x_sp, {"k": ck, "v": cv, "pos": cpos}

    def cell(params, token, caches, pos):
        idx = lax.axis_index(model_axis)
        b_loc = token.shape[0]
        b_sp = b_loc // tp
        pos = pos.astype(jnp.int32)
        tok_sp = lax.dynamic_slice_in_dim(token[:, 0], idx * b_sp, b_sp, 0)
        x_sp = (jnp.take(params["embed"], tok_sp, axis=0)
                * jnp.asarray(d ** 0.5, model.opt.dtype))
        new_layers = []
        for l in range(cfg.num_layers):
            if scanned:
                pl = jax.tree.map(lambda a: a[l], params["layers"])
                cl = {k_: caches[k_][l] for k_ in ("k", "v", "pos")}
            else:
                pl = params["layers"][l]
                cl = caches[l]
            x_sp, nl = _layer(pl, x_sp, cl, pos, idx)
            new_layers.append(nl)
        if scanned:
            new_caches = {k_: jnp.stack([nl[k_] for nl in new_layers])
                          for k_ in ("k", "v", "pos")}
        else:
            new_caches = new_layers
        xn = rms_norm(x_sp, params["final_norm"], cfg.norm_eps)
        wout = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        logits = cm.ag_matmul(xn, wout, model_axis, mode, chunks)
        return logits.astype(jnp.float32)[:, None, :], new_caches

    def _cache_spec(path, leaf):
        last = getattr(path[-1], "key", None)
        nd = len(leaf.shape)
        if last == "pos":                       # (..., slots, w)
            return P(*(None,) * (nd - 2), data_axis, None)
        return P(*(None,) * (nd - 4), data_axis, None, model_axis, None)

    def step(params, token, caches, pos):
        b = token.shape[0]
        if b % (dp * tp):
            raise ValueError(
                f"slots ({b}) must divide over data*model = {dp * tp} for "
                f"the sequence-parallel ring schedule")
        cspecs = jax.tree_util.tree_map_with_path(_cache_spec, caches)
        f = jax.shard_map(
            cell, mesh=mesh,
            in_specs=(P(), P(data_axis, None), cspecs, P(data_axis)),
            out_specs=(P(data_axis, None, None), cspecs),
            check_vma=False)
        return f(params, token, caches, pos)

    return step
