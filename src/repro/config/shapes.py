"""Assigned input-shape set (same 4 shapes for every LM-family arch).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV cache
of ``seq_len``), NOT ``train_step``. ``long_500k`` requires sub-quadratic
attention and is skipped (with a recorded reason) for pure full-attention archs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4_096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32_768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524_288, global_batch=1, kind="decode"),
}


def shape_by_name(name: str) -> ShapeConfig:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown shape {name!r}; choose from {sorted(SHAPES)}") from None


def cell_is_runnable(model_subquadratic: bool, shape: ShapeConfig) -> bool:
    """long_500k only runs for sub-quadratic archs (SWA / SSM / hybrid)."""
    if shape.name == "long_500k":
        return model_subquadratic
    return True
