"""Config dataclasses for HDOT-JAX.

Pure-python (no jax import) so that configs can be loaded before device
initialization — required by the dry-run, which must set XLA_FLAGS before
anything touches jax.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    # Per-expert FFN hidden size (qwen3-moe uses fine-grained 768-wide experts).
    d_ff_expert: int = 14336
    # Capacity factor used by the dense-dispatch (GShard-style) path.
    capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD / state-space duality) parameters."""

    state_dim: int = 128
    head_dim: int = 64
    expand: int = 2          # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk_size: int = 256    # SSD block size == HDOT sequence subdomain

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style hybrid: pattern of 'rglru' and 'attn' blocks."""

    # repeating block pattern; recurrentgemma uses (rglru, rglru, attn)
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")
    lru_width: Optional[int] = None   # defaults to d_model
    local_window: int = 2048          # local attention window
    conv_kernel: int = 4


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder. The audio frontend is a STUB: input_specs
    provides precomputed frame embeddings (batch, enc_seq, d_model)."""

    enc_layers: int = 6
    enc_seq: int = 1500


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # defaults to d_model // num_heads
    qk_norm: bool = False
    sliding_window: Optional[int] = None   # SWA window (mixtral: 4096)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    # vlm stub: number of image patch embeddings prepended to the sequence
    num_vision_patches: int = 0
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------ helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """True if the arch supports O(1)-state / bounded-window decode, i.e.
        long_500k is runnable (SWA, SSM, RG-LRU hybrid)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None

    def num_params(self) -> int:
        """Total parameter count (embedding + per-layer weights). Used for the
        MODEL_FLOPS=6*N*D roofline term and for sanity-checking configs."""
        hd = self.resolved_head_dim
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def dense_ffn(d_ff: int) -> int:
            return 3 * d * d_ff  # SwiGLU: gate, up, down

        per_layer = 0
        if self.family in ("dense", "vlm"):
            per_layer = attn_params() + dense_ffn(self.d_ff)
            n_layers = self.num_layers
            total = per_layer * n_layers
        elif self.family == "moe":
            if self.moe is None:
                raise ValueError(
                    f"config {self.name!r}: family='moe' requires a MoEConfig "
                    f"on cfg.moe")
            ffn = self.moe.num_experts * 3 * d * self.moe.d_ff_expert
            router = d * self.moe.num_experts
            total = (attn_params() + ffn + router) * self.num_layers
        elif self.family == "ssm":
            if self.ssm is None:
                raise ValueError(
                    f"config {self.name!r}: family='ssm' requires an "
                    f"SSMConfig on cfg.ssm")
            di = self.ssm.d_inner(d)
            nh = self.ssm.num_heads(d)
            # in_proj produces [z, x, B, C, dt]; out_proj back to d
            in_proj = d * (2 * di + 2 * self.ssm.state_dim + nh)
            out_proj = di * d
            conv = self.ssm.conv_kernel * (di + 2 * self.ssm.state_dim)
            total = (in_proj + out_proj + conv + 2 * nh) * self.num_layers
        elif self.family == "hybrid":
            if self.hybrid is None:
                raise ValueError(
                    f"config {self.name!r}: family='hybrid' requires a "
                    f"HybridConfig on cfg.hybrid")
            w = self.hybrid.lru_width or d
            rglru = d * 2 * w + w * d + 3 * w + self.hybrid.conv_kernel * w
            pat = self.hybrid.pattern
            n_attn = sum(1 for p in pat if p == "attn")
            n_rec = len(pat) - n_attn
            blocks = self.num_layers
            attn_blocks = blocks * n_attn // len(pat)
            rec_blocks = blocks - attn_blocks
            total = attn_blocks * (attn_params() + dense_ffn(self.d_ff)) + rec_blocks * (
                rglru + dense_ffn(self.d_ff)
            )
        elif self.family == "encdec":
            if self.encdec is None:
                raise ValueError(
                    f"config {self.name!r}: family='encdec' requires an "
                    f"EncDecConfig on cfg.encdec")
            dec = (2 * attn_params() + dense_ffn(self.d_ff)) * self.num_layers
            enc = (attn_params() + dense_ffn(self.d_ff)) * self.encdec.enc_layers
            total = dec + enc
        else:  # pragma: no cover - guarded by registry
            raise ValueError(f"unknown family {self.family}")
        return total + emb

    def active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.num_params()
        if self.moe is None:
            raise ValueError(
                f"config {self.name!r}: family='moe' requires a MoEConfig "
                f"on cfg.moe")
        d = self.d_model
        inactive = (self.moe.num_experts - self.moe.top_k) * 3 * d * self.moe.d_ff_expert
        return self.num_params() - inactive * self.num_layers

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        kw: dict = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            vocab_size=256,
            sliding_window=64 if self.sliding_window else None,
        )
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2), d_ff_expert=64
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=16, head_dim=16, chunk_size=32)
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(self.hybrid, lru_width=128, local_window=32)
        if self.encdec is not None:
            kw["encdec"] = dataclasses.replace(self.encdec, enc_layers=2, enc_seq=64)
        if self.num_vision_patches:
            kw["num_vision_patches"] = 16
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ParallelConfig:
    """How the model is laid out on the mesh. Axes are logical; launch/mesh.py
    materializes ("pod", "data", "model")."""

    # fsdp shards params/optstate over these axes (ZeRO-3); data parallel axes.
    dp_axes: Tuple[str, ...] = ("pod", "data")
    tp_axis: str = "model"
    # sequence-parallel activations between blocks (shard seq over tp_axis)
    sequence_parallel: bool = True
    # 'none'   = two-phase (paper's MPI+OpenMP baseline): whole-tensor collectives
    # 'hdot'   = per-subdomain collectives in the dataflow (the paper's technique)
    overlap: str = "hdot"
    # HDOT over-decomposition degree at task level (chunks per shard);
    # mirrors the paper's "number of subdomains per rank".
    subdomains: int = 4
    # gradient-sync buckets for the zero-copy HDOT schedule (subdomains of
    # the parameter domain; each bucket is one multi-operand all-reduce)
    grad_buckets: int = 8
    # bucket emission order for the explicit schedules:
    #   'reverse_topo' — buckets cut along layer boundaries (leaf provenance
    #                    from models/*), collectives emitted last-backward-
    #                    first so the first reduction departs while earlier
    #                    layers' backward still computes
    #   'tree'         — legacy size-balanced buckets in pytree order
    bucket_order: str = "reverse_topo"
    # ZeRO-3: park params/opt-state as bucket-wise flat buffers sharded over
    # dp_axes (1/|dp| per-device residency); the explicit step all-gathers
    # buckets forward-order and reduce-scatters them reverse-topologically.
    # Requires the explicit-schedule (DP-only mesh) step.
    param_shard: bool = False
    # Streaming ZeRO-3: cut ONE bucket per layer (bucket_order forced to
    # 'layer') and emit each bucket's all-gather inside the remat region of
    # the layer that consumes it — the gathered buffer dies after that
    # layer's forward and the backward REGATHERS it in reverse order, so
    # peak live params ≈ shard + fsdp_working_set buckets instead of the
    # full tree. Needs param_shard=True and scan_layers=False (layer
    # boundaries must be visible to the gather schedule).
    fsdp_streaming: bool = False
    # Bound on simultaneously-live gathered buckets the streaming schedule
    # promises (head bucket + the layer in flight). The lint target and the
    # memory probe assert it; the step itself emits gathers point-of-use.
    fsdp_working_set: int = 2
    scan_layers: bool = True
    remat: str = "full"                # 'none' | 'full' | 'dots'
    # gradient accumulation microbatches (1 = no accumulation)
    accum_steps: int = 1
    # use ppermute-ring collective matmul for TP instead of plain all-gather
    collective_matmul: bool = False
    # MoE expert-parallel a2a over-decomposition degree Q (core.a2a_scan):
    # the dispatch/combine all-to-alls are chunked into Q capacity slices so
    # slice k+1's dispatch and slice k-1's combine overlap slice k's expert
    # FFN. 1 = monolithic a2a (the two-phase baseline); must divide the
    # per-shard expert capacity C.
    moe_a2a_chunks: int = 1
    # int8 error-feedback compression on the cross-pod gradient hop
    grad_compression: str = "none"     # 'none' | 'int8_ef'
    # measured-cost dynamic re-partitioning: every K steps, re-cut the
    # interior chunk grid from per-chunk wall-clock EMAs (core/cost.py) and
    # recompile only if the cut changed. 0 = static uniform cut (off).
    rebalance_every: int = 0

    def __post_init__(self):
        if self.rebalance_every < 0:
            raise ValueError(
                f"rebalance_every must be >= 0, got {self.rebalance_every}")
        if self.fsdp_working_set < 1:
            raise ValueError(
                f"fsdp_working_set must be >= 1, got {self.fsdp_working_set}")
        if self.fsdp_streaming and not self.param_shard:
            raise ValueError(
                "fsdp_streaming=True needs param_shard=True (it is a "
                "schedule for the ZeRO-3 flat-shard step)")
        if self.fsdp_streaming and self.scan_layers:
            raise ValueError(
                "fsdp_streaming=True needs scan_layers=False: per-layer "
                "gather placement requires the unrolled stack (the scanned "
                "lowering streams via stack_apply's scan-carried gather)")
        if self.fsdp_streaming and self.remat != "full":
            raise ValueError(
                "fsdp_streaming=True needs remat='full': the backward must "
                "REGATHER each layer's bucket inside its remat region "
                "('none' would keep every gathered buffer live to its "
                "backward use; 'dots' saves the gathered dot operands — "
                "both forfeit the streaming memory bound)")


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
