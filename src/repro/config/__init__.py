from repro.config.base import (
    ModelConfig,
    MoEConfig,
    SSMConfig,
    HybridConfig,
    EncDecConfig,
    ParallelConfig,
    TrainConfig,
    RunConfig,
)
from repro.config.shapes import ShapeConfig, SHAPES, shape_by_name
from repro.config.registry import ARCHS, get_arch, list_archs

__all__ = [
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "HybridConfig",
    "EncDecConfig",
    "ParallelConfig",
    "TrainConfig",
    "RunConfig",
    "ShapeConfig",
    "SHAPES",
    "shape_by_name",
    "ARCHS",
    "get_arch",
    "list_archs",
]
