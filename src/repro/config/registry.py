"""Architecture registry: ``--arch <id>`` → ModelConfig.

Both dashed ("mixtral-8x7b") and underscored ("mixtral_8x7b") ids resolve.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config.base import ModelConfig

# id → module under repro.configs
_ARCH_MODULES: Dict[str, str] = {
    "mixtral-8x7b": "mixtral_8x7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "qwen3-8b": "qwen3_8b",
    "internlm2-1.8b": "internlm2_1_8b",
    "llama3-405b": "llama3_405b",
    "granite-3-2b": "granite_3_2b",
    "llava-next-34b": "llava_next_34b",
    "mamba2-780m": "mamba2_780m",
    "whisper-base": "whisper_base",
    "recurrentgemma-2b": "recurrentgemma_2b",
}


def list_archs() -> List[str]:
    return sorted(_ARCH_MODULES)


def get_arch(arch_id: str) -> ModelConfig:
    key = arch_id.strip()
    if key not in _ARCH_MODULES:
        # accept underscore form
        undashed = {v: k for k, v in _ARCH_MODULES.items()}
        if key in undashed:
            key = undashed[key]
        else:
            raise KeyError(f"unknown arch {arch_id!r}; choose from {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[key]}")
    cfg: ModelConfig = mod.CONFIG
    if cfg.name != key:
        raise ValueError(
            f"registry mismatch: repro.configs.{_ARCH_MODULES[key]} declares "
            f"CONFIG.name={cfg.name!r} but is registered under {key!r}")
    return cfg


class _LazyArchDict(dict):
    """Mapping view that imports configs on first access."""

    def __missing__(self, key: str) -> ModelConfig:
        cfg = get_arch(key)
        self[key] = cfg
        return cfg

    def keys(self):  # type: ignore[override]
        return _ARCH_MODULES.keys()


ARCHS: Dict[str, ModelConfig] = _LazyArchDict()
