"""Logical-axis sharding rules with divisibility fixups.

Model code annotates tensors with LOGICAL axis names ("batch", "seq", "heads",
...). The launcher installs a :class:`ShardingContext` that maps logical names
to mesh axes. Resolution is *ordered and greedy with fixups*:

- each logical name carries a candidate list (first match wins);
- a candidate is accepted only if (a) none of its mesh axes were already used
  by an earlier dim of the same tensor and (b) the dim size is divisible by
  the product of the candidate's mesh axis sizes;
- otherwise the next candidate (ultimately `None` = replicate) is used.

This is what lets ONE rule set drive 10 architectures x 4 shapes x 2 meshes:
e.g. "heads->model" applies to llama3 (128/16) but silently degrades to
replicated for llava (56 heads), and "experts->model" applies to qwen3-moe
(128 experts) while mixtral (8 experts) falls through to TP over expert_mlp.
Every fixup is observable via `explain_pspec` and recorded by the dry-run.

Outside an installed context every helper is the identity, so model code runs
unchanged in single-device CPU tests.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Optional[Tuple[str, ...]]          # one candidate: mesh axes for a dim
Candidates = Sequence[MeshAxes]               # ordered candidates per logical axis

# --------------------------------------------------------------- default rules
# weight + activation logical axes. ("pod","data") collapses to the axes that
# exist in the mesh (single-pod meshes have no "pod").
DEFAULT_RULES: Dict[str, Candidates] = {
    # activations
    "batch": [("pod", "data"), ("data",), None],
    "seq": [("model",), None],          # sequence parallelism between blocks
    "kv_seq": [("model",), None],       # decode KV cache length (flash-decode split)
    "act_embed": [None],
    "act_heads": [("model",), None],
    "act_kv_heads": [("model",), None],
    # weights
    "embed": [("pod", "data"), ("data",), None],   # FSDP dim
    "mlp": [("model",), None],
    "heads": [("model",), None],
    "kv_heads": [("model",), None],
    "head_dim": [None],
    "vocab": [("model",), None],
    "experts": [("model",), None],
    "expert_mlp": [("model",), None],
    "lru": [("model",), None],
    "state": [None],
    "conv": [None],
    "layers": [None],                   # scanned-layer leading dim
    None: [None],
}


# Serving (decode) recipe: weights fully TP over (model x data) — decode
# re-gathers FSDP weights EVERY token otherwise (measured 13.8 MB/chip/layer
# on granite decode_32k, EXPERIMENTS §Perf cell C it.2). Batch rides only the
# pod axis (activations are tiny at decode); the KV cache seq-shards over
# (model, data) and flash-decode combines partial softmaxes across both.
SERVE_RULES: Dict[str, Candidates] = dict(DEFAULT_RULES)
SERVE_RULES.update({
    "batch": [("pod",), None],
    "seq": [None],
    "kv_seq": [("model", "data"), ("model",), None],
    "act_heads": [("model",), None],
    "act_kv_heads": [None],
    "embed": [("data",), None],
    "mlp": [("model", "data"), ("model",), None],
    "heads": [("model", "data"), ("model",), None],
    "kv_heads": [("model",), None],
    "head_dim": [("data",), None],
    "vocab": [("model", "data"), ("model",), None],
    "experts": [("model", "data"), ("model",), None],
    "expert_mlp": [("model", "data"), ("model",), None],
    "lru": [("model", "data"), ("model",), None],
})


# DP x SP recipe for small-d_model archs (§Perf global iteration): activations
# shard (batch x seq); heads/kv REPLICATE so attention partial-sums vanish and
# the only per-layer traffic is the FSDP weight gather (~3 x layer bytes) plus
# the tiny full-seq k/v gather. Head-TP (DEFAULT_RULES) only pays off when
# layer weights outweigh the (b_loc, s, d) activation slabs — measured
# crossover ~d_model 6k at batch 256/mesh 256 (EXPERIMENTS §Perf).
TRAIN_DP_RULES: Dict[str, Candidates] = dict(DEFAULT_RULES)
TRAIN_DP_RULES.update({
    "act_heads": [None],
    "act_kv_heads": [None],
})

def rules_for(kind: str, d_model: int = 0, family: str = "") -> Dict[str, Candidates]:
    """Sharding recipe per cell kind (train/prefill amortize weight gathers
    over many tokens -> FSDP; decode cannot -> full TP).

    NOTE: TRAIN_DP_RULES was hypothesized to beat head-TP for small d_model
    (weight gathers ~3x layer bytes << activation slabs) but MEASURED 1.4x
    WORSE on internlm2 train_4k (406 vs 283 GB/chip) and 2x the temp memory:
    the backward of the replicated k/v gather is a full-seq gradient
    reduction per layer, and replicated-head score tensors blow the remat
    working set. Refuted; kept for the record (EXPERIMENTS §Perf)."""
    if kind == "decode":
        return dict(SERVE_RULES)
    return dict(DEFAULT_RULES)


@dataclass
class ShardingContext:
    mesh: Mesh
    rules: Dict[str, Candidates] = field(default_factory=lambda: dict(DEFAULT_RULES))

    def axis_size(self, name: str) -> int:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape)).get(name, 1)


_LOCAL = threading.local()


def current_context() -> Optional[ShardingContext]:
    return getattr(_LOCAL, "ctx", None)


class use_sharding:
    """Context manager installing mesh+rules for logical resolution."""

    def __init__(self, mesh: Mesh, rules: Optional[Dict[str, Candidates]] = None):
        merged = dict(DEFAULT_RULES)
        if rules:
            merged.update(rules)
        self.ctx = ShardingContext(mesh, merged)

    def __enter__(self) -> ShardingContext:
        self._prev = current_context()
        _LOCAL.ctx = self.ctx
        return self.ctx

    def __exit__(self, *exc):
        _LOCAL.ctx = self._prev
        return False


class no_sharding:
    """Temporarily clear the logical-sharding context: `with_logical` becomes
    the identity. Required around shard_map bodies — inside a manual region
    the mesh axes are already consumed, and a GSPMD sharding constraint
    naming them is rejected."""

    def __enter__(self) -> None:
        self._prev = current_context()
        _LOCAL.ctx = None

    def __exit__(self, *exc):
        _LOCAL.ctx = self._prev
        return False


def _mesh_axes_present(ctx: ShardingContext, cand: MeshAxes) -> MeshAxes:
    if cand is None:
        return None
    present = tuple(a for a in cand if a in ctx.mesh.axis_names)
    return present or None


def resolve_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  ctx: Optional[ShardingContext] = None) -> P:
    """Resolve logical axes -> PartitionSpec for a concrete shape (see module
    docstring for the fixup policy)."""
    ctx = ctx or current_context()
    if ctx is None:
        return P()
    assert len(shape) == len(axes), (shape, axes)
    used: set = set()
    out: List[Union[None, str, Tuple[str, ...]]] = []
    for dim, name in zip(shape, axes):
        placed: MeshAxes = None
        for cand in ctx.rules.get(name, [None]):
            cand = _mesh_axes_present(ctx, cand)
            if cand is None:
                placed = None
                break
            if any(a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= ctx.axis_size(a)
            if prod <= 1 or dim % prod != 0:
                continue
            placed = cand
            break
        if placed is None:
            out.append(None)
        else:
            used.update(placed)
            out.append(placed if len(placed) > 1 else placed[0])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def explain_pspec(shape: Sequence[int], axes: Sequence[Optional[str]],
                  ctx: Optional[ShardingContext] = None) -> str:
    spec = resolve_pspec(shape, axes, ctx)
    return f"{tuple(shape)} {tuple(axes)} -> {spec}"


def with_logical(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Sharding constraint by logical axes; identity outside a context."""
    ctx = current_context()
    if ctx is None:
        return x
    spec = resolve_pspec(x.shape, axes, ctx)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


def named_sharding(shape: Sequence[int], axes: Sequence[Optional[str]],
                   ctx: Optional[ShardingContext] = None) -> Optional[NamedSharding]:
    ctx = ctx or current_context()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, resolve_pspec(shape, axes, ctx))
