from repro.sharding.rules import (
    DEFAULT_RULES,
    ShardingContext,
    current_context,
    resolve_pspec,
    use_sharding,
    with_logical,
)

__all__ = [
    "DEFAULT_RULES",
    "ShardingContext",
    "current_context",
    "resolve_pspec",
    "use_sharding",
    "with_logical",
]
