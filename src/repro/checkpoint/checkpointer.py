"""Atomic, async, resumable checkpointing.

Layout:  <dir>/step_<N>/arrays.npz + meta.json ;  <dir>/LATEST
Guarantees:
  * atomicity — writes land in ``tmp_<N>`` and are renamed (POSIX atomic) only
    after fsync; a crash mid-save never corrupts the previous checkpoint;
  * exact resume — meta.json carries the data-pipeline step and RNG state;
  * async — `AsyncCheckpointer` snapshots device arrays synchronously (cheap)
    and writes on a background thread, off the training critical path;
  * elastic — arrays are stored unsharded, so restore may target a different
    mesh/sharding (see checkpoint.elastic.reshard).

Scale note: at 1000-node scale arrays.npz becomes per-host shard files keyed
by the same tree paths; the single-file layout here is the single-process
degenerate case of that design.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any
_SEP = "|"
# ZeRO-3 flat-buffer key shape (core.overlap.FsdpGroup.key): bucket + dtype
_BUCKET_KEY = re.compile(r"^b\d+_\w+$")


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or arr.dtype.name in ("bfloat16",
                                                       "float8_e4m3fn",
                                                       "float8_e5m2"):
            # npz cannot represent ml_dtypes; store widened (lossless for
            # bf16/f8 -> f32). Restore casts back via the target's dtype.
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _bucket_keys(keys) -> Tuple[str, ...]:
    """The FSDP flat-buffer names among `keys` (path segments like
    ``b03_bfloat16``) — the part of the tree that is layout-dependent."""
    return tuple(sorted({seg for k in keys for seg in k.split(_SEP)
                         if _BUCKET_KEY.match(seg)}))


def _unflatten_into(target: PyTree, arrays: Dict[str, np.ndarray]) -> PyTree:
    paths, treedef = jax.tree_util.tree_flatten_with_path(target)
    want = [_SEP.join(_path_str(p) for p in path) for path, _ in paths]
    leaves = []
    for key, (path, leaf) in zip(want, paths):
        if key not in arrays:
            want_b, have_b = _bucket_keys(want), _bucket_keys(arrays)
            if want_b and have_b and want_b != have_b:
                raise ValueError(
                    f"checkpoint FSDP layout mismatch: the restore target "
                    f"expects flat buffers {list(want_b)} but the checkpoint "
                    f"holds {list(have_b)} — a grad_buckets / bucket_order / "
                    "mesh-size change re-cuts the layout. Import the "
                    "checkpoint with checkpoint.restore_fsdp_checkpoint "
                    "(unshards with the OLD FsdpLayout, reshards with the "
                    "new) instead of restoring it structurally.")
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = arrays[key]
        dtype = getattr(leaf, "dtype", None)
        if dtype is not None and arr.dtype != dtype:
            arr = arr.astype(dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree: PyTree,
                    extra: Optional[Dict] = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    arrays = _flatten(tree)
    tmp = os.path.join(directory, f"tmp_{step}_{os.getpid()}")
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": int(step), "extra": extra or {}}, f)
        f.flush()
        os.fsync(f.fileno())
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    steps = sorted(
        int(d.split("_", 1)[1]) for d in os.listdir(directory)
        if d.startswith("step_"))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s}"), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore_checkpoint(directory: str, target: PyTree,
                       step: Optional[int] = None,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[int, PyTree, Dict]:
    """Restore into the structure of `target` (arrays or ShapeDtypeStructs).
    With `shardings` (a matching tree of NamedSharding), leaves are placed
    sharded — this is also the elastic-resharding path."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    tree = _unflatten_into(target, arrays)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return int(meta["step"]), tree, meta.get("extra", {})


def restore_fsdp_checkpoint(directory: str, old_layout, new_layout,
                            step: Optional[int] = None, sharding=None
                            ) -> Tuple[int, PyTree, Dict]:
    """Re-layout import path for ZeRO-3 trainer state: restore a checkpoint
    written under `old_layout` (some grad_buckets / bucket_order / mesh size)
    and re-cut its flat buffers — params AND f32 optimizer moments — into
    `new_layout` (core.overlap.fsdp_relayout: unshard with the OLD layout,
    reshard with the NEW). Bit-exact: only pad elements are dropped/re-added.

    Returns ``(step, {"params": flat, "opt": {...}}, extra)`` keyed by the
    NEW layout. With `sharding` (one NamedSharding, typically
    ``P(dp_axes)``), every flat buffer is placed on it."""
    import jax.numpy as jnp

    from repro.core.overlap import fsdp_relayout

    def flat_target(layout, dtype=None):
        return {g.key: jax.ShapeDtypeStruct((g.padded,),
                                            jnp.dtype(dtype or g.dtype))
                for g in layout.groups}

    target = {"params": flat_target(old_layout),
              "opt": {"m": flat_target(old_layout, np.float32),
                      "v": flat_target(old_layout, np.float32),
                      "step": jax.ShapeDtypeStruct((), np.int32)}}
    step, tree, extra = restore_checkpoint(directory, target, step)
    out = {"params": fsdp_relayout(tree["params"], old_layout, new_layout),
           "opt": {"m": fsdp_relayout(tree["opt"]["m"], old_layout, new_layout),
                   "v": fsdp_relayout(tree["opt"]["v"], old_layout, new_layout),
                   "step": jnp.asarray(tree["opt"]["step"])}}
    if sharding is not None:
        out["params"] = {k: jax.device_put(v, sharding)
                         for k, v in out["params"].items()}
        for mom in ("m", "v"):
            out["opt"][mom] = {k: jax.device_put(v, sharding)
                               for k, v in out["opt"][mom].items()}
    return step, out, extra


class AsyncCheckpointer:
    """Snapshot synchronously, write asynchronously (one in flight)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None) -> None:
        self.wait()
        arrays_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, arrays_tree, extra, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
