from repro.checkpoint.checkpointer import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    restore_fsdp_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.elastic import reshard

__all__ = [
    "AsyncCheckpointer",
    "latest_step",
    "restore_checkpoint",
    "restore_fsdp_checkpoint",
    "save_checkpoint",
    "reshard",
]
