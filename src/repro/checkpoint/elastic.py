"""Elastic re-meshing: restore a checkpoint onto a different mesh.

Because checkpoints store logically-global arrays and shardings are derived
from logical axes (sharding.rules), changing the mesh (e.g. 2x16x16 ->
1x8x16 after losing a pod) only changes where `resolve_pspec` places each
dim — the restore path re-places every leaf under the new context. Data-order
determinism is preserved by the stateless pipeline (step index alone).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.sharding.rules import ShardingContext, resolve_pspec

PyTree = Any


def shardings_for(tree_specs: PyTree, axes: PyTree, mesh: Mesh,
                  ctx: Optional[ShardingContext] = None) -> PyTree:
    """NamedSharding tree from (ShapeDtypeStruct|array tree, logical-axes tree)."""
    ctx = ctx or ShardingContext(mesh)

    def one(leaf, ax):
        return NamedSharding(mesh, resolve_pspec(leaf.shape, ax, ctx))

    return jax.tree.map(one, tree_specs, axes,
                        is_leaf=lambda x: hasattr(x, "shape"))


def reshard(tree: PyTree, axes: PyTree, mesh: Mesh,
            ctx: Optional[ShardingContext] = None) -> PyTree:
    """Re-place an in-memory tree under a (new) mesh."""
    sh = shardings_for(tree, axes, mesh, ctx)
    return jax.tree.map(lambda a, s: jax.device_put(a, s), tree, sh)
