"""Expert-parallel MoE (shard_map a2a) vs the dense-dispatch oracle.

With ample capacity (no token drops) the two paths are the same function;
grads must also agree (a2a transposes to a2a). The capacity-chunked a2a_scan
schedule (a2a_chunks=Q) must be a pure schedule change: same loss bit-exact,
grads equal up to the per-slice accumulation reordering the capacity
reduction (one ulp)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_devices(code: str, devices: int, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_moe_ep_matches_dense_oracle():
    code = """
    import json, dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.config.registry import get_arch
    from repro.models import moe as moe_mod
    from repro.models.layers import init_from_specs
    from repro.launch.mesh import make_mesh
    from repro.sharding.rules import use_sharding

    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    # ample capacity: no drops -> EP and dense are the same function
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                     capacity_factor=8.0))
    p = init_from_specs(moe_mod.moe_specs(cfg, jnp.float32),
                        jax.random.PRNGKey(0))
    mesh = make_mesh((2, 4), ("data", "model"))
    B, S, D = 4, 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32) * 0.3

    def loss_dense(p, x):
        y, aux = moe_mod.moe_apply_dense(p, x, cfg)
        return jnp.sum(y * y) + aux

    def loss_ep(p, x):
        with use_sharding(mesh):
            from repro.sharding.rules import current_context
            y, aux = moe_mod.moe_apply_ep(p, x, cfg, current_context())
        return jnp.sum(y * y) + aux

    with use_sharding(mesh):
        ld, gd = jax.jit(jax.value_and_grad(loss_dense))(p, x)
    le, ge = jax.jit(jax.value_and_grad(loss_ep))(p, x)
    gerr = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(ge)))
    print(json.dumps({
        "loss_dense": float(ld), "loss_ep": float(le),
        "loss_err": abs(float(ld) - float(le)),
        "grad_err": gerr,
    }))
    """
    r = run_devices(code, 8)
    assert r["loss_err"] < 1e-3 * (1 + abs(r["loss_dense"])), r
    assert r["grad_err"] < 2e-3, r


@pytest.mark.slow
def test_moe_ep_a2a_chunks_equivalence():
    """Q in {1, 2, 4}: the chunked dispatch/combine must compute the same
    function as the monolithic (Q=1) schedule — loss bit-exact (the output
    is a concatenation of per-slice results, no reassociation), grads equal
    up to one f32 ulp (weight grads accumulate per slice, reordering the
    capacity-dim reduction) — and stay within the dense-oracle tolerance."""
    code = """
    import json, dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.config.registry import get_arch
    from repro.models import moe as moe_mod
    from repro.models.layers import init_from_specs
    from repro.launch.mesh import make_mesh
    from repro.sharding.rules import use_sharding

    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                     capacity_factor=8.0))
    p = init_from_specs(moe_mod.moe_specs(cfg, jnp.float32),
                        jax.random.PRNGKey(0))
    mesh = make_mesh((2, 4), ("data", "model"))
    B, S, D = 4, 32, cfg.d_model   # S_loc=8 -> C=16, divisible by 1/2/4
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32) * 0.3

    def loss_dense(p, x):
        y, aux = moe_mod.moe_apply_dense(p, x, cfg)
        return jnp.sum(y * y) + aux

    with use_sharding(mesh):
        ld, gd = jax.jit(jax.value_and_grad(loss_dense))(p, x)

    out = {}
    by_q = {}
    for q in (1, 2, 4):
        def loss_ep(p, x, q=q):
            with use_sharding(mesh):
                from repro.sharding.rules import current_context
                y, aux = moe_mod.moe_apply_ep(p, x, cfg, current_context(),
                                              a2a_chunks=q)
            return jnp.sum(y * y) + aux

        le, ge = jax.jit(jax.value_and_grad(loss_ep))(p, x)
        by_q[q] = (float(le), ge)
        out[f"dense_grad_err_q{q}"] = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(ge)))
    l1, g1 = by_q[1]
    for q in (2, 4):
        lq, gq = by_q[q]
        out[f"loss_delta_q{q}"] = abs(lq - l1)
        out[f"mono_grad_err_q{q}"] = max(
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gq)))
    print(json.dumps(out))
    """
    r = run_devices(code, 8)
    for q in (2, 4):
        assert r[f"loss_delta_q{q}"] == 0.0, r       # pure concatenation
        assert r[f"mono_grad_err_q{q}"] < 1e-4, r    # reassociation ulps
        assert r[f"dense_grad_err_q{q}"] < 2e-3, r   # same as the Q=1 oracle


@pytest.mark.slow
def test_moe_ep_a2a_lint_target_and_monolithic_fixture():
    """The canonical lm_moe_ep lint target (Q=2, grad of the EP layer) must
    pass all rules at max_exposed_collectives=0 — PAIR-COUNT pins 4*Q=8
    all-to-alls (dispatch+combine per slice, forward and backward) — while
    the monolithic fixture must trip exactly NO-OVERLAP-WINDOW: its a2a
    count is the *correct* monolithic 4, but the forward dispatch/combine
    have zero dataflow-independent compute to hide behind."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    rep = lint_target("lm_moe_ep")
    broken = lint_target("broken_monolithic_a2a_moe")
    rules = {f.rule for f in broken.errors}
    print(json.dumps({
        "canonical_ok": rep.ok,
        "monolithic_window_caught": "NO-OVERLAP-WINDOW" in rules,
        "monolithic_pair_count_green": "PAIR-COUNT" not in rules,
    }))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


# ------------------------------------------------ fast validation (no mesh)
class _StubCtx:
    """Minimal sharding-context stand-in: moe_apply_ep validates divisibility
    before touching params or building the shard_map, so a bare axis_size()
    is all it needs to prove the ValueErrors fire at trace time."""

    def __init__(self, n: int):
        self.n = n

    def axis_size(self, name: str) -> int:
        return self.n


def _reduced_cfg():
    from repro.config.registry import get_arch

    return get_arch("qwen3-moe-30b-a3b").reduced()   # E=4, K=2, cf=1.25


def test_moe_ep_rejects_indivisible_experts():
    import jax.numpy as jnp

    from repro.models import moe as moe_mod

    cfg = _reduced_cfg()
    x = jnp.zeros((2, 12, cfg.d_model), jnp.float32)
    with pytest.raises(ValueError, match="num_experts=4 is not divisible"):
        moe_mod.moe_apply_ep({}, x, cfg, _StubCtx(3))


def test_moe_ep_rejects_indivisible_tokens():
    import jax.numpy as jnp

    from repro.models import moe as moe_mod

    cfg = _reduced_cfg()
    x = jnp.zeros((2, 13, cfg.d_model), jnp.float32)
    with pytest.raises(ValueError, match="token dim"):
        moe_mod.moe_apply_ep({}, x, cfg, _StubCtx(2))


def test_moe_ep_rejects_indivisible_capacity_chunks():
    import jax.numpy as jnp

    from repro.models import moe as moe_mod

    cfg = _reduced_cfg()
    # n=2, S=32 -> S_loc=16 -> C = ceil(16*2/4 * 1.25) = 10; 10 % 3 != 0
    x = jnp.zeros((2, 32, cfg.d_model), jnp.float32)
    with pytest.raises(ValueError, match="a2a_chunks=3"):
        moe_mod.moe_apply_ep({}, x, cfg, _StubCtx(2), a2a_chunks=3)


def test_a2a_scan_rejects_indivisible_chunks():
    import jax.numpy as jnp

    from repro.core.a2a_scan import a2a_scan

    with pytest.raises(ValueError, match="chunks=3"):
        a2a_scan(jnp.zeros((4, 10, 8)), lambda v, k: v, "model",
                 chunks=3, dim=1)


@pytest.mark.slow
def test_moe_ep_decode_batch_as_tokens():
    """S=1 (decode) routes through EP with the batch swapped into the token
    slot — must equal the dense dispatch."""
    code = """
    import json, dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.config.registry import get_arch
    from repro.models import moe as moe_mod
    from repro.models.layers import init_from_specs
    from repro.launch.mesh import make_mesh
    from repro.sharding.rules import use_sharding, rules_for

    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                     capacity_factor=8.0))
    p = init_from_specs(moe_mod.moe_specs(cfg, jnp.float32),
                        jax.random.PRNGKey(0))
    mesh = make_mesh((2, 4), ("data", "model"))
    B, D = 8, cfg.d_model           # S = 1 decode step, B divisible by model=4
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, D), jnp.float32) * 0.3

    y_dense, _ = jax.jit(lambda p, x: moe_mod.moe_apply_dense(p, x, cfg))(p, x)

    def ep(p, x):
        with use_sharding(mesh, rules_for("decode")):
            return moe_mod.moe_apply(p, x, cfg)

    y_ep, _ = jax.jit(ep)(p, x)
    err = float(jnp.max(jnp.abs(y_dense - y_ep)))
    print(json.dumps({"err": err}))
    """
    r = run_devices(code, 8)
    assert r["err"] < 2e-4, r
