"""Expert-parallel MoE (shard_map a2a) vs the dense-dispatch oracle.

With ample capacity (no token drops) the two paths are the same function;
grads must also agree (a2a transposes to a2a)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_devices(code: str, devices: int, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_moe_ep_matches_dense_oracle():
    code = """
    import json, dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.config.registry import get_arch
    from repro.models import moe as moe_mod
    from repro.models.layers import init_from_specs
    from repro.launch.mesh import make_mesh
    from repro.sharding.rules import use_sharding

    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    # ample capacity: no drops -> EP and dense are the same function
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                     capacity_factor=8.0))
    p = init_from_specs(moe_mod.moe_specs(cfg, jnp.float32),
                        jax.random.PRNGKey(0))
    mesh = make_mesh((2, 4), ("data", "model"))
    B, S, D = 4, 32, cfg.d_model
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, D), jnp.float32) * 0.3

    def loss_dense(p, x):
        y, aux = moe_mod.moe_apply_dense(p, x, cfg)
        return jnp.sum(y * y) + aux

    def loss_ep(p, x):
        with use_sharding(mesh):
            from repro.sharding.rules import current_context
            y, aux = moe_mod.moe_apply_ep(p, x, cfg, current_context())
        return jnp.sum(y * y) + aux

    with use_sharding(mesh):
        ld, gd = jax.jit(jax.value_and_grad(loss_dense))(p, x)
    le, ge = jax.jit(jax.value_and_grad(loss_ep))(p, x)
    gerr = max(float(jnp.max(jnp.abs(a - b)))
               for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(ge)))
    print(json.dumps({
        "loss_dense": float(ld), "loss_ep": float(le),
        "loss_err": abs(float(ld) - float(le)),
        "grad_err": gerr,
    }))
    """
    r = run_devices(code, 8)
    assert r["loss_err"] < 1e-3 * (1 + abs(r["loss_dense"])), r
    assert r["grad_err"] < 2e-3, r


@pytest.mark.slow
def test_moe_ep_decode_batch_as_tokens():
    """S=1 (decode) routes through EP with the batch swapped into the token
    slot — must equal the dense dispatch."""
    code = """
    import json, dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.config.registry import get_arch
    from repro.models import moe as moe_mod
    from repro.models.layers import init_from_specs
    from repro.launch.mesh import make_mesh
    from repro.sharding.rules import use_sharding, rules_for

    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                     capacity_factor=8.0))
    p = init_from_specs(moe_mod.moe_specs(cfg, jnp.float32),
                        jax.random.PRNGKey(0))
    mesh = make_mesh((2, 4), ("data", "model"))
    B, D = 8, cfg.d_model           # S = 1 decode step, B divisible by model=4
    x = jax.random.normal(jax.random.PRNGKey(1), (B, 1, D), jnp.float32) * 0.3

    y_dense, _ = jax.jit(lambda p, x: moe_mod.moe_apply_dense(p, x, cfg))(p, x)

    def ep(p, x):
        with use_sharding(mesh, rules_for("decode")):
            return moe_mod.moe_apply(p, x, cfg)

    y_ep, _ = jax.jit(ep)(p, x)
    err = float(jnp.max(jnp.abs(y_dense - y_ep)))
    print(json.dumps({"err": err}))
    """
    r = run_devices(code, 8)
    assert r["err"] < 2e-4, r
