"""Fused linear-xent custom VJP vs the naive oracle: loss exact, grads within
bf16-cotangent tolerance (the deliberate approximation is dlogits -> bf16)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.xent import linear_xent, xent_ref


def _setup(dtype=jnp.float32, b=2, s=16, d=32, v=64):
    k = jax.random.PRNGKey(0)
    x = (jax.random.normal(k, (b, s, d)) * 0.5).astype(dtype)
    w = (jax.random.normal(jax.random.fold_in(k, 1), (d, v)) * 0.1).astype(dtype)
    t = jax.random.randint(jax.random.fold_in(k, 2), (b, s), 0, v)
    return x, w, t


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_loss_matches_reference(dtype):
    x, w, t = _setup(dtype)
    got = float(linear_xent(x, w, t))
    want = float(xent_ref(x, w, t))
    np.testing.assert_allclose(got, want, rtol=1e-5 if dtype == jnp.float32
                               else 2e-2)


def test_grads_match_reference_fp32():
    x, w, t = _setup(jnp.float32)
    g1 = jax.grad(linear_xent, argnums=(0, 1))(x, w, t)
    g2 = jax.grad(xent_ref, argnums=(0, 1))(x, w, t)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-5)


def test_grads_reasonable_bf16():
    x, w, t = _setup(jnp.bfloat16)
    g1 = jax.grad(linear_xent, argnums=(0, 1))(x, w, t)
    g2 = jax.grad(xent_ref, argnums=(0, 1))(x, w, t)
    for a, b_ in zip(g1, g2):
        a32 = np.asarray(a, np.float32)
        b32 = np.asarray(b_, np.float32)
        denom = np.maximum(np.abs(b32).max(), 1e-6)
        assert np.abs(a32 - b32).max() / denom < 0.05


def test_grad_direction_decreases_loss():
    x, w, t = _setup(jnp.float32)
    g = jax.grad(linear_xent, argnums=1)(x, w, t)
    w2 = w - 0.1 * g
    assert float(linear_xent(x, w2, t)) < float(linear_xent(x, w, t))


def test_model_train_loss_still_finite_all_archs():
    """The fused tail is wired into every family's train_loss."""
    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model

    for arch in ("qwen3-8b", "mixtral-8x7b", "mamba2-780m", "whisper-base"):
        cfg = get_arch(arch).reduced()
        m = build_model(cfg, ModelOptions(attn_impl="dense"))
        p = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((2, 32), jnp.int32),
                 "targets": jnp.ones((2, 32), jnp.int32)}
        if cfg.family == "encdec":
            batch["frames"] = jnp.zeros((2, cfg.encdec.enc_seq, cfg.d_model),
                                        jnp.bfloat16)
        loss, grads = jax.value_and_grad(m.train_loss)(p, batch)
        assert np.isfinite(float(loss))
