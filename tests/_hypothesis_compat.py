"""Graceful degradation when `hypothesis` is not installed.

The container image does not ship hypothesis, and a bare ``from hypothesis
import given`` at module scope killed collection of the ENTIRE tier-1 suite.
Test modules import ``given/settings/st`` from here instead: with hypothesis
present they are the real thing; without it, ``@given`` turns the test into a
skip (reason recorded) while every non-property test in the module still runs.
"""
from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only in the bare image
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub of `hypothesis.strategies`: any strategy call returns None —
        the decorated test is skipped before the value is ever used."""

        def __getattr__(self, name):
            def strategy(*_args, **_kwargs):
                return None
            return strategy

    st = _Strategies()
