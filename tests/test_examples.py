"""The shipped examples must actually run — CI includes them. train_lm's
loss assert was flaky at small step counts (the whole run sat inside LR
warmup, where first-vs-last loss is noise); it now checks the post-warmup
trend, or a sanity bound when the run never leaves warmup. Both paths are
exercised here via the CLI, exactly as CI / a user invokes them."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_example(args, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run([sys.executable, *args], capture_output=True,
                          text=True, timeout=timeout, env=env, cwd=REPO)


def test_train_lm_steps4_inside_warmup(tmp_path):
    """4 steps sit entirely inside warmup: the example must pass on the
    sanity-bound path (this exact invocation failed at baseline)."""
    out = run_example(["examples/train_lm.py", "--preset", "2m",
                       "--steps", "4", "--ckpt-dir", str(tmp_path)])
    assert out.returncode == 0, out.stderr[-3000:]
    assert "[train_lm] OK" in out.stdout, out.stdout[-2000:]
    assert "inside warmup" in out.stdout, out.stdout[-2000:]


@pytest.mark.slow
def test_train_lm_post_warmup_trend(tmp_path):
    """A run that clears warmup must pass the real improvement assert."""
    out = run_example(["examples/train_lm.py", "--preset", "2m",
                       "--steps", "40", "--ckpt-dir", str(tmp_path)])
    assert out.returncode == 0, out.stderr[-3000:]
    assert "post-warmup loss decreased" in out.stdout, out.stdout[-2000:]
