"""Shared fixtures. Tests run on exactly ONE CPU device — device-count forcing
is reserved for the dry-run and the benchmark subprocess workers."""
from __future__ import annotations

import os

# Guard: if a stray XLA_FLAGS leaked in, tests would silently exercise the
# wrong configuration.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "tests must run with the default single CPU device"

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def single_mesh():
    """1-device mesh carrying the production axis names."""
    from repro.launch.mesh import make_single_device_mesh

    return make_single_device_mesh()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced(arch_id: str):
    from repro.config.registry import get_arch

    return get_arch(arch_id).reduced()
