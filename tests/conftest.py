"""Shared fixtures. Tests run on exactly ONE CPU device — device-count forcing
is reserved for the dry-run and the benchmark subprocess workers."""
from __future__ import annotations

import os
import re

# Guard: the in-process suite must see the default single CPU device. CI
# exports XLA_FLAGS=--xla_force_host_platform_device_count=8 at the job level
# (for ad-hoc scripts and the benchmark drivers), so strip the forcing flag
# here — before jax initializes its backend — instead of failing outright.
# The multi-device subprocess workers are unaffected: run_devices() in
# test_system.py and benchmarks/_util.run_worker() overwrite XLA_FLAGS in the
# child environment with their own device counts.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" in _flags:
    os.environ["XLA_FLAGS"] = re.sub(
        r"--xla_force_host_platform_device_count=\d+", "", _flags).strip()

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def single_mesh():
    """1-device mesh carrying the production axis names."""
    from repro.launch.mesh import make_single_device_mesh

    return make_single_device_mesh()


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def reduced(arch_id: str):
    from repro.config.registry import get_arch

    return get_arch(arch_id).reduced()
