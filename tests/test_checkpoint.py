"""Checkpointing: atomic save/restore, GC, async writer, elastic resharding."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.checkpoint.checkpointer import save_checkpoint
from repro.checkpoint.elastic import reshard, shardings_for


def _tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.ones((3,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = _tree()
    save_checkpoint(d, 10, tree, extra={"data_step": 10})
    assert latest_step(d) == 10
    step, restored, extra = restore_checkpoint(d, tree)
    assert step == 10 and extra["data_step"] == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_gc_keeps_latest_k(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        save_checkpoint(d, s, _tree(), keep=3)
    kept = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                  if x.startswith("step_"))
    assert kept == [3, 4, 5]
    assert latest_step(d) == 5


def test_restore_picks_latest_not_partial(tmp_path):
    """A crash mid-write leaves a tmp_ dir; restore must ignore it."""
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, _tree())
    os.makedirs(os.path.join(d, "tmp_2_9999"))  # simulated torn write
    assert latest_step(d) == 1
    step, _, _ = restore_checkpoint(d, _tree())
    assert step == 1


def test_async_checkpointer_overlaps_and_surfaces_errors(tmp_path):
    d = str(tmp_path / "ckpt")
    ck = AsyncCheckpointer(d, keep=2)
    ck.save(1, _tree())
    ck.save(2, _tree())       # waits for save 1 internally
    ck.wait()
    assert latest_step(d) == 2

    bad = AsyncCheckpointer("/proc/definitely/not/writable", keep=1)
    bad.save(1, _tree())
    with pytest.raises(BaseException):
        bad.wait()


def test_elastic_reshard_roundtrip(tmp_path, single_mesh):
    """Save under one mesh, restore under another (axis sizes 1 here, but the
    code path — resolve, device_put with new shardings — is the real one)."""
    d = str(tmp_path / "ckpt")
    tree = {"w": jnp.arange(8.0).reshape(2, 4)}
    axes = {"w": ("embed", "mlp")}
    placed = reshard(tree, axes, single_mesh)
    save_checkpoint(d, 3, placed)
    sh = shardings_for(tree, axes, single_mesh)
    _, restored, _ = restore_checkpoint(d, tree, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    assert restored["w"].sharding == sh["w"]
