"""Gradient-sync schedules, bucketing and microbatch accumulation (core.overlap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.overlap import (accumulate_grads, grad_sync, make_buckets,
                                microbatch_split)


@given(sizes=st.lists(st.integers(1, 1000), min_size=1, max_size=20),
       k=st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_make_buckets_partition(sizes, k):
    """Every leaf appears exactly once across buckets, order preserved inside."""
    tree = {f"w{i}": jnp.zeros((s,)) for i, s in enumerate(sizes)}
    buckets = make_buckets(tree, k)
    seen = [i for b in buckets for i, _ in b]
    assert sorted(seen) == list(range(len(sizes)))
    for b in buckets:
        idxs = [i for i, _ in b]
        assert idxs == sorted(idxs)


@given(sizes=st.lists(st.integers(100, 1000), min_size=4, max_size=16))
@settings(max_examples=50, deadline=None)
def test_make_buckets_balanced(sizes):
    """Greedy balance: max bucket <= sum/k + max leaf (classic LPT bound)."""
    k = 4
    tree = {f"w{i}": jnp.zeros((s,)) for i, s in enumerate(sizes)}
    buckets = make_buckets(tree, k)
    loads = [sum(int(l.size) for _, l in b) for b in buckets]
    assert max(loads) <= sum(sizes) / min(k, len(sizes)) + max(sizes)


def test_grad_sync_modes_identical_single_device(single_mesh):
    """On axis size 1 both schedules are the identity (psum over size-1)."""
    import functools

    from jax.sharding import PartitionSpec as P

    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((7,)), "c": jnp.asarray(2.0)}

    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(grad_sync, axes="data", mode=mode),
            mesh=single_mesh, in_specs=(P(),), out_specs=P()))
        out = f(tree)
        for k in tree:
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.asarray(tree[k]), rtol=1e-6)


@pytest.mark.parametrize("steps", [1, 2, 4])
def test_accumulate_grads_linearity(steps):
    """Accumulated mean-loss grads == full-batch grads for a loss that is a
    mean over examples (linearity of grad in the batch)."""
    w = jnp.asarray([1.0, -2.0, 0.5])

    def loss_fn(w, batch):
        pred = batch["x"] @ w
        return jnp.mean((pred - batch["y"]) ** 2)

    k = jax.random.PRNGKey(0)
    batch = {"x": jax.random.normal(k, (8, 3)),
             "y": jax.random.normal(jax.random.fold_in(k, 1), (8,))}

    def lg(w, b):
        return jax.value_and_grad(loss_fn)(w, b)

    loss_a, g_a = accumulate_grads(lg, w, batch, steps)
    loss_f, g_f = lg(w, batch)
    np.testing.assert_allclose(float(loss_a), float(loss_f), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_f),
                               rtol=1e-5, atol=1e-6)


def test_microbatch_split_roundtrip():
    batch = {"tokens": jnp.arange(24).reshape(8, 3)}
    mb = microbatch_split(batch, 4)
    assert mb["tokens"].shape == (4, 2, 3)
    np.testing.assert_array_equal(
        np.asarray(mb["tokens"].reshape(8, 3)), np.asarray(batch["tokens"]))


def test_microbatch_split_requires_divisibility():
    """A real ValueError naming the sizes — not a bare assert that vanishes
    under `python -O` into a shapeless reshape error."""
    with pytest.raises(ValueError, match="batch 6.*accum steps 4"):
        microbatch_split({"x": jnp.zeros((6, 2))}, 4)


# ------------------------------------------------- zero-copy bucketed sync
def _mixed_tree():
    """Integer-valued mixed-dtype gradients: bf16 sums are exact, so the
    schedules must agree bit-for-bit."""
    k = jax.random.PRNGKey(0)
    return {
        "emb": jax.random.randint(k, (16, 8), -4, 5).astype(jnp.bfloat16),
        "w1": jax.random.randint(jax.random.fold_in(k, 1), (32,), -4, 5
                                 ).astype(jnp.float32),
        "w2": jax.random.randint(jax.random.fold_in(k, 2), (4, 4), -4, 5
                                 ).astype(jnp.float16),
        "b": jnp.asarray(3.0),
    }


def _sync_fn(mode, mesh):
    import functools

    from jax.sharding import PartitionSpec as P

    return jax.jit(jax.shard_map(
        functools.partial(grad_sync, axes="data", mode=mode, num_buckets=2),
        mesh=mesh, in_specs=(P(),), out_specs=P()))


def test_grad_sync_hdot_mixed_dtype_matches_two_phase(single_mesh):
    tree = _mixed_tree()
    out_hd = _sync_fn("hdot", single_mesh)(tree)
    out_tp = _sync_fn("two_phase", single_mesh)(tree)
    for k in tree:
        assert out_hd[k].dtype == tree[k].dtype, k   # no dtype round-trip
        np.testing.assert_array_equal(
            np.asarray(out_hd[k], np.float32), np.asarray(out_tp[k], np.float32))


def test_grad_sync_hdot_is_zero_copy(single_mesh):
    """The structural claim of the optimization: the hdot sync path stages no
    concatenated flat buffer (the two-phase baseline does)."""
    tree = _mixed_tree()
    hlo_hd = _sync_fn("hdot", single_mesh).lower(tree).as_text()
    hlo_tp = _sync_fn("two_phase", single_mesh).lower(tree).as_text()
    assert "concatenate" not in hlo_hd
    assert "concatenate" in hlo_tp
