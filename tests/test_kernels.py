"""Per-kernel validation: Pallas (interpret=True on CPU) vs the pure-jnp
ref.py oracle, swept over shapes/dtypes; plus algorithm-level properties
(chunked SSD == sequential recurrence, red-black GS convergence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.heat2d import ops as heat_ops
from repro.kernels.lru_scan import ops as lru_ops
from repro.kernels.lru_scan import ref as lru_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref


def _key(i=0):
    return jax.random.PRNGKey(i)


# ------------------------------------------------------------ flash attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,sk,hq,hkv,d,causal,window", [
    (1, 256, 256, 4, 4, 64, True, None),      # MHA causal
    (2, 256, 256, 8, 2, 64, True, None),      # GQA 4:1
    (1, 512, 512, 4, 1, 128, True, 128),      # MQA + sliding window
    (1, 128, 128, 2, 2, 32, False, None),     # bidirectional
])
def test_flash_vs_ref(b, sq, sk, hq, hkv, d, causal, window, dtype):
    k0 = _key(0)
    q = jax.random.normal(k0, (b, sq, hq, d), dtype)
    k = jax.random.normal(_key(1), (b, sk, hkv, d), dtype)
    v = jax.random.normal(_key(2), (b, sk, hkv, d), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                 impl="pallas", interpret=True,
                                 block_q=128, block_k=128)
    want = fa_ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_block_shape_invariance():
    """Result must not depend on the BlockSpec tile choice."""
    q = jax.random.normal(_key(0), (1, 512, 4, 64), jnp.float32)
    k = jax.random.normal(_key(1), (1, 512, 2, 64), jnp.float32)
    v = jax.random.normal(_key(2), (1, 512, 2, 64), jnp.float32)
    outs = [fa_ops.flash_attention(q, k, v, impl="pallas", interpret=True,
                                   block_q=bq, block_k=bk)
            for bq, bk in [(128, 128), (256, 128), (128, 256), (512, 512)]]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------- heat2d
@pytest.mark.parametrize("n,tile", [(128, (64, 64)), (256, (128, 128)),
                                    (256, (256, 256))])
def test_heat2d_pallas_vs_ref(n, tile):
    u = jax.random.normal(_key(3), (n, n), jnp.float32)
    got = heat_ops.heat2d_sweep(u, tile=tile, impl="pallas", interpret=True)
    want = heat_ops.heat2d_sweep(u, tile=tile, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_heat2d_sweeps_converge():
    """Red-black GS on the Laplace problem must contract toward 0 with
    Dirichlet-0 boundaries."""
    u = jnp.ones((128, 128), jnp.float32)
    norms = [float(jnp.abs(u).mean())]
    for _ in range(5):
        u = heat_ops.heat2d_sweep(u, tile=(128, 128), sweeps=4, impl="ref")
        norms.append(float(jnp.abs(u).mean()))
    assert norms[-1] < norms[0]
    assert all(b <= a + 1e-6 for a, b in zip(norms, norms[1:]))


# ----------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 128, 2, 16, 8, 32),
    (2, 256, 4, 32, 16, 64),
    (1, 64, 1, 8, 4, 64),      # single chunk
])
def test_ssd_pallas_vs_ref(b, l, h, p, n, chunk, dtype):
    x = jax.random.normal(_key(0), (b, l, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(_key(1), (b, l, h)))
    A = -jnp.exp(jax.random.normal(_key(2), (h,)) * 0.2)
    B = jax.random.normal(_key(3), (b, l, n), dtype)
    C = jax.random.normal(_key(4), (b, l, n), dtype)
    yp, sp = ssd_ops.ssd(x, dt, A, B, C, chunk, impl="pallas", interpret=True)
    yr, sr = ssd_ref.ssd_ref(x, dt, A, B, C, chunk)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(yp, np.float32),
                               np.asarray(yr, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=tol, atol=tol)


@given(chunk=st.sampled_from([8, 16, 32, 64]))
@settings(max_examples=8, deadline=None)
def test_ssd_chunked_equals_sequential(chunk):
    """The chunked SSD algorithm (any chunk size) must equal the O(l)
    sequential recurrence — the state hand-off is the sequence 'halo'."""
    b, l, h, p, n = 1, 64, 2, 8, 4
    x = jax.random.normal(_key(0), (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(_key(1), (b, l, h)))
    A = -jnp.exp(jax.random.normal(_key(2), (h,)) * 0.2)
    B = jax.random.normal(_key(3), (b, l, n), jnp.float32)
    C = jax.random.normal(_key(4), (b, l, n), jnp.float32)
    yc, sc = ssd_ref.ssd_ref(x, dt, A, B, C, chunk)
    ys, ss = ssd_ref.ssd_sequential(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(ys),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(ss),
                               rtol=1e-3, atol=1e-3)


def test_ssd_decode_matches_prefill():
    """Decoding one token against the prefill-final state must equal running
    the full sequence one step longer."""
    b, l, h, p, n = 1, 32, 2, 8, 4
    x = jax.random.normal(_key(0), (b, l + 1, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(_key(1), (b, l + 1, h)))
    A = -jnp.exp(jax.random.normal(_key(2), (h,)) * 0.2)
    B = jax.random.normal(_key(3), (b, l + 1, n), jnp.float32)
    C = jax.random.normal(_key(4), (b, l + 1, n), jnp.float32)
    _, state = ssd_ref.ssd_ref(x[:, :l], dt[:, :l], A, B[:, :l], C[:, :l], 16)
    y1, s1 = ssd_ref.ssd_decode_step_ref(state, x[:, l], dt[:, l], A,
                                         B[:, l], C[:, l])
    y_full, s_full = ssd_ref.ssd_sequential(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_full[:, -1]),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s_full),
                               rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------- lru scan
@pytest.mark.parametrize("b,l,w", [(1, 64, 16), (2, 128, 32), (1, 33, 8)])
def test_lru_pallas_vs_ref(b, l, w):
    a = jax.random.uniform(_key(0), (b, l, w), minval=0.5, maxval=0.99)
    x = jax.random.normal(_key(1), (b, l, w))
    hp, lp = lru_ops.lru_scan(a, x, impl="pallas", interpret=True)
    hr, lr = lru_ref.lru_scan_ref(a, x)
    np.testing.assert_allclose(np.asarray(hp), np.asarray(hr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lr),
                               rtol=1e-5, atol=1e-5)


def test_lru_ref_vs_sequential():
    a = jax.random.uniform(_key(0), (2, 64, 8), minval=0.1, maxval=0.95)
    x = jax.random.normal(_key(1), (2, 64, 8))
    h0 = jax.random.normal(_key(2), (2, 8))
    hr, lr = lru_ref.lru_scan_ref(a, x, h0)
    hs, ls = lru_ref.lru_scan_sequential(a, x, h0)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(hs),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(ls),
                               rtol=1e-5, atol=1e-5)


def test_heat2d_pallas_strip_halos_multi_tile_multi_sweep():
    """Strip-halo staging must reproduce the full-tile oracle when halos cross
    many tile boundaries and sweeps>1 reuse the VMEM-resident tile."""
    u = jax.random.normal(_key(7), (128, 128), jnp.float32)
    for tile, sweeps in [((32, 64), 3), ((64, 64), 2), ((128, 128), 4)]:
        got = heat_ops.heat2d_sweep(u, tile=tile, sweeps=sweeps,
                                    impl="pallas", interpret=True)
        want = heat_ops.heat2d_sweep(u, tile=tile, sweeps=sweeps, impl="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
