"""halo.py schedule machinery: interior chunk tasks, pre-exchanged-halo apply,
and the double-buffered multi-step `halo_scan` driver. All single-device (the
multi-device equivalences live in test_system.py); numerics must be identical
between every schedule/knob setting — the paper's safety property."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.halo import (exchange_halo, halo_scan, stencil_apply,
                             stencil_with_halo)


@pytest.fixture(scope="module")
def data_mesh():
    from repro.launch.mesh import make_mesh

    return make_mesh((1,), ("data",))


def _avg3(padded: jax.Array) -> jax.Array:
    """width-1 moving average along dim 0 (any trailing dims)."""
    return (padded[:-2] + padded[1:-1] + padded[2:]) / 3.0


def _d2w2(padded: jax.Array) -> jax.Array:
    """width-2 second difference along dim 0 (5-point)."""
    return (padded[:-4] - 0.5 * padded[1:-3] + padded[2:-2]
            - 0.5 * padded[3:-1] + padded[4:])


def _shmap(fn, mesh):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P("data"),),
                                 out_specs=P("data")))


@pytest.mark.parametrize("subdomains", [1, 2, 3, 4, 16])
@pytest.mark.parametrize("periodic", [False, True])
def test_stencil_hdot_subdomains_match_two_phase(data_mesh, subdomains, periodic):
    """The interior chunk knob must not change numerics for any grainsize."""
    u = jax.random.normal(jax.random.PRNGKey(0), (24, 5), jnp.float32)
    want = _shmap(lambda x: stencil_apply(
        x, _avg3, "data", 1, 0, periodic, "two_phase"), data_mesh)(u)
    got = _shmap(lambda x: stencil_apply(
        x, _avg3, "data", 1, 0, periodic, "hdot", subdomains), data_mesh)(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("mode", ["hdot", "two_phase"])
@pytest.mark.parametrize("width,fn", [(1, _avg3), (2, _d2w2)])
def test_halo_scan_equals_iterated_apply(data_mesh, mode, width, fn):
    """halo_scan(steps=k) == k iterated stencil_apply calls, both schedules."""
    steps = 5
    u = jax.random.normal(jax.random.PRNGKey(1), (32, 4), jnp.float32)

    got, _ = jax.jit(jax.shard_map(
        lambda x: halo_scan(x, fn, "data", width, 0, steps, periodic=True,
                            mode=mode),
        mesh=data_mesh, in_specs=(P("data"),),
        out_specs=(P("data"), P())))(u)

    def iterate(x):
        for _ in range(steps):
            x = stencil_apply(x, fn, "data", width, 0, True, mode)
        return x

    want = _shmap(iterate, data_mesh)(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_halo_scan_step_outputs(data_mesh):
    """step_out_fn results are stacked per step, in order."""
    u = jnp.ones((16, 3), jnp.float32)
    _, outs = jax.jit(jax.shard_map(
        lambda x: halo_scan(x, _avg3, "data", 1, 0, 4, periodic=True,
                            step_out_fn=lambda new, old: jax.lax.pmax(
                                jnp.max(jnp.abs(new - old)), "data")),
        mesh=data_mesh, in_specs=(P("data"),),
        out_specs=(P("data"), P())))(u)
    assert outs.shape == (4,)
    np.testing.assert_allclose(np.asarray(outs), 0.0, atol=1e-7)  # constant field


def test_halo_scan_degenerate_block_falls_back(data_mesh):
    """Blocks with no interior (< 4*width rows) still produce identical
    numerics via the two-phase fallback."""
    u = jax.random.normal(jax.random.PRNGKey(2), (6, 3), jnp.float32)  # < 4*2
    got, _ = jax.jit(jax.shard_map(
        lambda x: halo_scan(x, _d2w2, "data", 2, 0, 3, periodic=True),
        mesh=data_mesh, in_specs=(P("data"),),
        out_specs=(P("data"), P())))(u)

    def iterate(x):
        for _ in range(3):
            x = stencil_apply(x, _d2w2, "data", 2, 0, True, "two_phase")
        return x

    want = _shmap(iterate, data_mesh)(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_stencil_with_halo_uses_given_halos(data_mesh):
    """stencil_with_halo(u, lo, hi) == two-phase apply on concat([lo, u, hi])."""
    u = jax.random.normal(jax.random.PRNGKey(3), (20, 4), jnp.float32)
    lo = jax.random.normal(jax.random.PRNGKey(4), (1, 4), jnp.float32)
    hi = jax.random.normal(jax.random.PRNGKey(5), (1, 4), jnp.float32)
    got = jax.jit(functools.partial(stencil_with_halo, stencil_fn=_avg3,
                                    width=1, dim=0, subdomains=3))(u, lo, hi)
    want = _avg3(jnp.concatenate([lo, u, hi], axis=0))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_halo_scan_peel_numerics_identical(data_mesh):
    """Peeling the drain step is schedule-only: bit-identical results and
    per-step outputs vs the unpeeled scan (the ppermute-count drop itself
    needs a real multi-device axis — asserted in test_system.py)."""
    u = jax.random.normal(jax.random.PRNGKey(7), (32, 4), jnp.float32)

    def run(peel):
        return jax.jit(jax.shard_map(
            lambda x: halo_scan(x, _avg3, "data", 1, 0, 5, periodic=True,
                                peel=peel,
                                step_out_fn=lambda new, old: jax.lax.pmax(
                                    jnp.max(new), "data")),
            mesh=data_mesh, in_specs=(P("data"),),
            out_specs=(P("data"), P())))(u)

    u_p, outs_p = run(True)
    u_n, outs_n = run(False)
    np.testing.assert_array_equal(np.asarray(u_p), np.asarray(u_n))
    assert outs_p.shape == outs_n.shape == (5,)
    np.testing.assert_array_equal(np.asarray(outs_p), np.asarray(outs_n))


def test_exchange_edges_single_rank(data_mesh):
    """Size-1 axis: periodic wraps own edges, non-periodic returns zeros."""
    u = jnp.arange(12.0).reshape(6, 2)

    def ex(x, periodic):
        return exchange_halo(x, "data", 1, 0, periodic)

    lo_p, hi_p = jax.jit(jax.shard_map(
        functools.partial(ex, periodic=True), mesh=data_mesh,
        in_specs=(P("data"),), out_specs=(P("data"), P("data"))))(u)
    np.testing.assert_array_equal(np.asarray(lo_p), np.asarray(u[-1:]))
    np.testing.assert_array_equal(np.asarray(hi_p), np.asarray(u[:1]))

    lo_z, hi_z = jax.jit(jax.shard_map(
        functools.partial(ex, periodic=False), mesh=data_mesh,
        in_specs=(P("data"),), out_specs=(P("data"), P("data"))))(u)
    np.testing.assert_array_equal(np.asarray(lo_z), 0.0)
    np.testing.assert_array_equal(np.asarray(hi_z), 0.0)
