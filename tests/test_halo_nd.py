"""N-D decomposition machinery, single-device (the 2x2x2 / 4x2x1 real-mesh
equivalences live in test_system.py). The safety property is the same at
every depth of the hierarchy: every schedule/knob/topology must be
numerically identical to the two-phase oracle — including the corner and
edge cells, which the corner-free exchange must still get right for star
stencils on all three axes at once."""
from __future__ import annotations

import functools
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.domain import interior_boxes
from repro.core.halo import (exchange_halo_nd, halo_scan_nd,
                             pad_with_halo_nd, stencil_apply_nd,
                             stencil_with_halo_nd)

AXES3 = ("planes", "rows", "cols")
DECOMP3 = tuple(zip(AXES3, (0, 1, 2)))


@pytest.fixture(scope="module")
def grid_mesh3():
    from repro.launch.mesh import make_grid_mesh

    return make_grid_mesh(1, 1, 1)


def _star3_fn(width: int):
    """Separable 3-D star stencil of `width` (reads the full 3-axis cross,
    never a corner). Input padded by `width` on all three dims; returns the
    un-padded update."""
    def fn(p):
        w = width
        n0, n1, n2 = (s - 2 * w for s in p.shape)
        acc = 0.0
        for d in range(-w, w + 1):
            acc = (acc
                   + p[w + d:w + d + n0, w:w + n1, w:w + n2]
                   + p[w:w + n0, w + d:w + d + n1, w:w + n2]
                   + p[w:w + n0, w:w + n1, w + d:w + d + n2])
        return acc / (3 * (2 * w + 1))
    return fn


def _shmap(fn, mesh):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P(*AXES3),),
                                 out_specs=P(*AXES3)))


def test_interior_boxes_partition_3d():
    """The task-level chunk grid tiles exactly the interior of the block —
    the process partition scheme applied one level down, in 3-D."""
    shape, w, grid = (13, 11, 9), 2, (3, 2, 2)
    boxes = interior_boxes(shape, w, grid)
    assert len(boxes) == 12
    cells = set()
    for b in boxes:
        for idx in itertools.product(*(range(a, o) for a, o in
                                       zip(b.start, b.stop))):
            assert idx not in cells
            cells.add(idx)
    want = set(itertools.product(*(range(w, s - w) for s in shape)))
    assert cells == want


@pytest.mark.parametrize("subdomains", [(1, 1, 1), (2, 2, 2), (3, 2, 1), 2,
                                        (8, 8, 8)])
@pytest.mark.parametrize("periodic", [False, True])
def test_stencil_hdot_nd_matches_two_phase(grid_mesh3, subdomains, periodic):
    """The 3-D chunk-grid knob must not change numerics for any grainsize."""
    u = jax.random.normal(jax.random.PRNGKey(0), (16, 14, 12), jnp.float32)
    fn = _star3_fn(1)
    want = _shmap(lambda x: stencil_apply_nd(
        x, fn, DECOMP3, 1, periodic, "two_phase"), grid_mesh3)(u)
    got = _shmap(lambda x: stencil_apply_nd(
        x, fn, DECOMP3, 1, periodic, "hdot", subdomains), grid_mesh3)(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("mode", ["hdot", "two_phase"])
@pytest.mark.parametrize("width,shape", [(1, (11, 9, 13)), (1, (12, 10, 8)),
                                         (2, (13, 11, 10))])
def test_halo_scan_nd_equals_iterated_apply(grid_mesh3, mode, width, shape):
    """halo_scan_nd(steps=k) == k iterated 3-D applies, odd AND even
    extents, both schedules."""
    steps = 3
    u = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    fn = _star3_fn(width)

    got, _ = jax.jit(jax.shard_map(
        lambda x: halo_scan_nd(x, fn, DECOMP3, width, steps, periodic=True,
                               mode=mode, subdomains=(2, 2, 1)),
        mesh=grid_mesh3, in_specs=(P(*AXES3),),
        out_specs=(P(*AXES3), P())))(u)

    def iterate(x):
        for _ in range(steps):
            x = stencil_apply_nd(x, fn, DECOMP3, width, True, "two_phase")
        return x

    want = _shmap(iterate, grid_mesh3)(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_stencil_with_halo_nd_uses_given_halos():
    """Pre-exchanged face halos (random, not wrap-around) flow into the
    right cells — including every edge/corner region, via the corner-free
    face assembly."""
    k = jax.random.PRNGKey(2)
    u = jax.random.normal(k, (12, 10, 14), jnp.float32)
    halos = []
    for d, s in enumerate(u.shape):
        shp = list(u.shape)
        shp[d] = 1
        halos.append(
            (jax.random.normal(jax.random.fold_in(k, 2 * d + 1), shp),
             jax.random.normal(jax.random.fold_in(k, 2 * d + 2), shp)))
    fn = _star3_fn(1)
    got = jax.jit(functools.partial(stencil_with_halo_nd, stencil_fn=fn,
                                    width=1, dims=(0, 1, 2),
                                    subdomains=(2, 1, 3)))(u, halos)
    want = fn(pad_with_halo_nd(u, halos, 1, (0, 1, 2)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_exchange_halo_nd_periodic_wraps_own_edges(grid_mesh3):
    """Size-1 axes: periodic wraps each dim's own edges (the N-D analogue of
    the 1-D single-rank contract)."""
    u = jnp.arange(2.0 * 3 * 4).reshape(2, 3, 4)

    def ex(x):
        halos = exchange_halo_nd(x, DECOMP3, 1, periodic=True)
        return tuple(h for pair in halos for h in pair)

    out = jax.jit(jax.shard_map(
        ex, mesh=grid_mesh3, in_specs=(P(*AXES3),),
        out_specs=tuple(P(*AXES3) for _ in range(6))))(u)
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(u[-1:]))
    np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(u[:1]))
    np.testing.assert_array_equal(np.asarray(out[2]), np.asarray(u[:, -1:]))
    np.testing.assert_array_equal(np.asarray(out[4]),
                                  np.asarray(u[:, :, -1:]))


def test_rk3_2d_mesh_matches_slab(grid_mesh3):
    """rk3_solve on a 1x1 (rows, cols) topology == the z-slab solver, both
    schedules (stage-carried halos on BOTH axes)."""
    from repro.core.stencil import rk3_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh

    v0 = jax.random.normal(jax.random.PRNGKey(3), (12, 20, 32), jnp.float32)
    want = rk3_solve(v0, make_mesh((1,), ("data",)), "data", 4, dt=0.01,
                     mode="two_phase")
    for mode in ("two_phase", "hdot"):
        got = rk3_solve(v0, make_grid_mesh(1, 1), ("rows", "cols"), 4,
                        dt=0.01, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_hpccg_3d_mesh_matches_slab(grid_mesh3):
    """CG on the full (x, y, z) topology converges identically to the z-slab
    solver — exercises the chained sequential exchange end to end."""
    from repro.core.stencil import hpccg_solve
    from repro.launch.mesh import make_mesh

    b = jax.random.normal(jax.random.PRNGKey(4), (10, 12, 12), jnp.float32)
    _, h_want = hpccg_solve(b, make_mesh((1,), ("data",)), "data", 15,
                            mode="two_phase")
    for mode in ("two_phase", "hdot"):
        _, h = hpccg_solve(b, grid_mesh3, AXES3, 15, mode=mode)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_want),
                                   rtol=1e-4)
