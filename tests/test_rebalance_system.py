"""System behaviour of the dynamic re-partitioning loop (slow).

The straggler drill runs REAL OS processes (multiprocessing spawn, numpy-only
workers); the lint check lowers the weighted-cut heat2d program under 4
forced host devices and proves the re-cut preserved the corner-free onion
schedule — zero exposed collectives, same ppermute count as the uniform cut.
"""
from __future__ import annotations

import pytest

from tests.test_system import run_devices


@pytest.mark.slow
def test_straggler_drill_dynamic_beats_static():
    """One worker slowed 3x: the measured-cost re-cut must shift rows away
    from the straggler and recover >= 1.2x throughput over the static
    uniform cut, without changing the numerics."""
    from repro.runtime.rebalance import straggler_drill_compare

    r = straggler_drill_compare(workers=4, rows=64, cols=64, steps=20,
                                warmup=4, rebalance_every=4, slow_worker=0,
                                slow_factor=3.0, seconds_per_cell=8e-6)
    st, dy = r["static"], r["dynamic"]
    assert r["speedup"] >= 1.2, r["speedup"]
    assert len(st["cut_history"]) == 1          # static never re-cuts
    assert len(dy["cut_history"]) >= 2          # dynamic did
    assert dy["extents"][0] < st["extents"][0]  # straggler's band shrank
    assert st["max_err"] < 1e-6 and dy["max_err"] < 1e-6
    # the straggler's measured per-cell rate is visibly the hot one
    assert dy["rates"][0] > 2.0 * dy["rates"][1]


@pytest.mark.slow
def test_straggler_drill_worker_death_reassigns():
    """Killing a worker mid-run reroutes its band to a survivor via
    reassign_host_shards; the stitched field still matches the oracle."""
    from repro.runtime.rebalance import straggler_drill

    d = straggler_drill(workers=4, rows=48, cols=32, steps=10, warmup=2,
                        rebalance_every=4, slow_worker=0, slow_factor=1.0,
                        seconds_per_cell=4e-6, dynamic=True,
                        fail_worker=2, fail_at_step=4)
    assert d["failed"] == [2]
    assert d["owner"][2] != 2           # the dead worker's band was rerouted
    assert d["owner"][2] in (0, 1, 3)
    assert d["max_err"] < 1e-6


@pytest.mark.slow
def test_weighted_cut_lowers_to_clean_overlap_schedule():
    """The heat2d_weighted lint target: an uneven measured-cost cut on a 2x2
    mesh must lower to the exact onion schedule of the uniform cut — the
    expected ppermute total and ZERO exposed collectives (faces depend on the
    halo width, never on where the interior is cut)."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    weighted = lint_target("heat2d_weighted")
    uniform = lint_target("heat2d_2d")
    print(json.dumps({
        "weighted_ok": weighted.ok,
        "weighted_errors": [f.rule for f in weighted.errors],
        "uniform_ok": uniform.ok,
    }))
    """
    r = run_devices(code, 4)
    assert r["weighted_ok"], r["weighted_errors"]
    assert r["uniform_ok"]


@pytest.mark.slow
def test_drill_validation():
    from repro.runtime.rebalance import straggler_drill

    with pytest.raises(ValueError, match="warmup"):
        straggler_drill(steps=4, warmup=4)
    with pytest.raises(ValueError, match="slow_worker"):
        straggler_drill(workers=2, slow_worker=5)
    with pytest.raises(ValueError, match="go together"):
        straggler_drill(fail_worker=1)
