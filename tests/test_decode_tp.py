"""TP-sharded continuous decode (models.decode_tp) vs the 1-device oracle.

The TP cell must be a pure schedule change: same greedy tokens as the plain
`model.decode_step` continuous server. The comparison runs in f32 — the ring
reduce-scatter reassociates the cross-rank partial sums, which in bf16 is a
1-ulp perturbation per layer, enough to flip near-tie argmaxes; in f32 the
drift (~1e-6 relative) is orders of magnitude below any logit margin, so
greedy outputs are token-exact and the assertion is deterministic.

The lint half mirrors tests/test_moe_ep.py: the canonical `lm_decode_tp`
target must pass every rule at max_exposed_collectives=0 (PAIR-COUNT pins
(4L+1) rings x 2 permutes), while the two-phase fixture must trip exactly
NO-OVERLAP-WINDOW.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_devices(code: str, devices: int, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_decode_tp_matches_oracle_4dev():
    """(2 data x 2 model) mesh, 6 requests through 4 slots with refills:
    token-exact greedy agreement with the single-device continuous server,
    including non-trivial slot admission mid-stream."""
    code = """
    import dataclasses, json
    import jax.numpy as jnp
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.models.decode_tp import build_decode_step
    from repro.models.model import ModelOptions, build_model, init_params
    from repro.runtime.server import BatchServer, Request

    cfg = dataclasses.replace(get_arch("qwen3-8b").reduced(), num_layers=2)
    opts = ModelOptions(attn_impl="dense", dtype=jnp.float32)
    model = build_model(cfg, opts)
    params = init_params(cfg, seed=0, options=opts)
    step = build_decode_step(model, make_mesh((2, 2), ("data", "model")))

    prompts = [[5, 9, 3], [7, 1], [2, 2, 2, 2, 8], [11], [4, 6], [1, 2, 3]]
    maxnew = [4, 6, 2, 1, 5, 3]

    def outputs(decode_fn):
        srv = BatchServer(model, params, slots=4, max_len=16,
                          decode_step_fn=decode_fn)
        for p, m in zip(prompts, maxnew):
            srv.submit(Request(prompt=list(p), max_new_tokens=m))
        return {r.rid: r.output for r in srv.run_continuous()}

    oracle, tp = outputs(None), outputs(step)
    print(json.dumps({
        "served": len(tp),
        "token_exact": oracle == tp,
    }))
    """
    r = run_devices(code, 4)
    assert r["served"] == 6, r
    assert r["token_exact"], r


@pytest.mark.slow
def test_decode_tp_lint_target_and_two_phase_fixture():
    """lm_decode_tp lints clean at zero exposed collectives — PAIR-COUNT
    pins the (4L+1)*pieces*(tp-1) ring permutes derived from the runtime's
    own `ring_permute_count` — while the two-phase fixture (serial
    all_gather/psum_scatter walls) trips exactly NO-OVERLAP-WINDOW, with its
    pair count (0 permutes) green so the failure is the schedule shape."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    rep = lint_target("lm_decode_tp")
    broken = lint_target("broken_two_phase_decode_tp")
    rules = {f.rule for f in broken.errors}
    print(json.dumps({
        "canonical_ok": rep.ok,
        "two_phase_window_caught": "NO-OVERLAP-WINDOW" in rules,
        "two_phase_pair_count_green": "PAIR-COUNT" not in rules,
    }))
    """
    r = run_devices(code, 2)
    assert all(r.values()), r


# ------------------------------------------------ fast validation (no mesh)
class _StubMesh:
    """build_decode_step validates divisibility from mesh.shape alone, before
    any device is touched."""

    def __init__(self, dp: int, tp: int):
        self.shape = {"data": dp, "model": tp}


def _model(arch="qwen3-8b", family_override=None):
    import dataclasses

    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model

    cfg = get_arch(arch).reduced()
    if family_override:
        cfg = dataclasses.replace(cfg, family=family_override)
    return build_model(cfg, ModelOptions(attn_impl="dense"))


def test_decode_tp_rejects_indivisible_heads():
    from repro.models.decode_tp import build_decode_step

    with pytest.raises(ValueError, match="heads"):
        build_decode_step(_model(), _StubMesh(1, 3))   # 4 q / 2 kv vs tp=3


def test_decode_tp_rejects_non_dense_family():
    from repro.models.decode_tp import build_decode_step

    with pytest.raises(ValueError, match="dense family"):
        build_decode_step(_model("qwen3-moe-30b-a3b"), _StubMesh(1, 2))


def test_expected_permutes_derive_from_ring_pieces():
    """The lint arithmetic must move with the runtime's chunk policy."""
    from repro.core.collective_matmul import ring_permute_count
    from repro.models.decode_tp import expected_permute_total

    cfg = _model().cfg                                  # L = 4
    # slots=8, dp=1, tp=2: s_sp=4 -> 2 bidirectional pieces x 1 hop
    assert ring_permute_count(4, 2) == 2
    assert expected_permute_total(cfg, 8, 1, 2) == (4 * 4 + 1) * 2
    assert expected_permute_total(cfg, 8, 1, 2, chunks=4) == (4 * 4 + 1) * 4
    assert ring_permute_count(4, 1) == 0                # tp=1: no rings
