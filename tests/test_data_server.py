"""Data pipeline determinism/sharding + serving runtime behaviour."""
from __future__ import annotations

import jax
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import SyntheticLMDataset


def _ds(**kw):
    d = dict(vocab_size=97, seq_len=16, global_batch=8, seed=5)
    d.update(kw)
    return SyntheticLMDataset(**d)


def test_batches_deterministic():
    a = _ds().batch_at(3)
    b = _ds().batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(_ds().batch_at(4)["tokens"], a["tokens"])


def test_targets_are_next_tokens():
    b = _ds(noise=0.0, a=31).batch_at(0)
    # noiseless: affine chain t+1 = (a*t + b) % V
    nxt = (b["tokens"].astype(np.int64) * 31 + 7) % 97
    np.testing.assert_array_equal(b["targets"], nxt)


@given(num_hosts=st.sampled_from([1, 2, 4, 8]), step=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_host_slices_tile_global_batch(num_hosts, step):
    ds = _ds()
    full = ds.batch_at(step)
    parts = [ds.host_slice(step, h, num_hosts) for h in range(num_hosts)]
    np.testing.assert_array_equal(
        np.concatenate([p["tokens"] for p in parts], axis=0), full["tokens"])


def test_state_roundtrip():
    ds = _ds()
    st8 = ds.state(8)
    assert SyntheticLMDataset.resume_step(st8) == 8


# ------------------------------------------------------------------ server
def test_server_waves_and_lengths():
    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model
    from repro.runtime.server import BatchServer, Request

    cfg = get_arch("internlm2-1.8b").reduced()
    import dataclasses

    cfg = dataclasses.replace(cfg, num_layers=2)
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for i in range(5):
        server.submit(Request(prompt=rng.integers(1, 100, 6).tolist(),
                              max_new_tokens=4 + i))
    served = server.run_all()
    assert len(served) == 5
    for i, r in enumerate(served):
        assert len(r.output) == 4 + i
        assert all(0 <= t < cfg.vocab_size for t in r.output)


def test_server_greedy_matches_manual_decode():
    """Server output must equal hand-rolled prefill+argmax decode."""
    import dataclasses
    import jax.numpy as jnp

    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model
    from repro.runtime.server import BatchServer, Request

    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(),
                              num_layers=2)
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    prompt = [5, 17, 29, 3]
    n_new = 5

    server = BatchServer(model, params, slots=1, max_len=64)
    server.submit(Request(prompt=prompt, max_new_tokens=n_new))
    out_server = server.run_all()[0].output

    toks = jnp.asarray([prompt], jnp.int32)
    logits, caches = model.prefill(params, {"tokens": toks}, max_len=64)
    out_manual = []
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    pos = len(prompt)
    for _ in range(n_new):
        out_manual.append(int(tok[0, 0]))
        logits, caches = model.decode_step(params, tok, caches,
                                           jnp.asarray(pos, jnp.int32))
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        pos += 1
    assert out_server == out_manual


def test_server_eos_stops_early():
    import dataclasses

    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model
    from repro.runtime.server import BatchServer, Request

    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(),
                              num_layers=1)
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, params, slots=1, max_len=64)
    # discover the greedy first token, then use it as EOS: output length 1
    server.submit(Request(prompt=[1, 2, 3], max_new_tokens=8))
    first = server.run_all()[0].output[0]
    server.submit(Request(prompt=[1, 2, 3], max_new_tokens=8, eos_id=first))
    out = server.run_all()[0].output
    assert out == [first]
