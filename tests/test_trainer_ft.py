"""Integration: the Trainer end-to-end — loss goes down, checkpoint/restart
is exact (same data order, same trajectory), fault injection recovers."""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.config.base import ParallelConfig, RunConfig, TrainConfig
from repro.config.registry import get_arch
from repro.runtime.ft import FaultTolerantRunner
from repro.runtime.trainer import Trainer


def _run(tmp_path, steps=6, every=2, arch="internlm2-1.8b", accum=1):
    cfg = get_arch(arch).reduced()
    cfg = dataclasses.replace(cfg, num_layers=2)
    return RunConfig(
        model=cfg,
        parallel=ParallelConfig(remat="none", accum_steps=accum),
        train=TrainConfig(global_batch=4, seq_len=32, lr=5e-3,
                          warmup_steps=2, total_steps=steps,
                          checkpoint_every=every,
                          checkpoint_dir=str(tmp_path / "ckpt"),
                          keep_checkpoints=2, seed=3))


@pytest.mark.slow
def test_loss_decreases(tmp_path):
    trainer = Trainer(_run(tmp_path, steps=30))
    trainer.train(30)
    losses = [m["loss"] for m in trainer.metrics_log]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_restart_is_exact(tmp_path):
    """Train 6 straight vs train 4 + restart + 2: identical final loss."""
    run = _run(tmp_path / "a", steps=6, every=2)
    t1 = Trainer(run)
    t1.train(6)

    run2 = _run(tmp_path / "b", steps=6, every=2)
    t2 = Trainer(run2)
    t2.train(4)
    del t2
    t3 = Trainer(run2)           # fresh process analogue
    assert t3.restore_if_available()
    assert t3.step == 4
    t3.train(2)
    np.testing.assert_allclose(t1.metrics_log[-1]["loss"],
                               t3.metrics_log[-1]["loss"], rtol=1e-4)


def test_fault_tolerant_runner_recovers(tmp_path):
    """Inject a failure at step 3; the controller restarts from the step-2
    checkpoint and completes all 6 steps."""
    run = _run(tmp_path, steps=6, every=2)
    fired = {"n": 0}

    def failure_hook(step):
        if step == 3 and fired["n"] == 0:
            fired["n"] += 1
            raise RuntimeError("injected node failure")

    runner = FaultTolerantRunner(lambda: Trainer(run), max_restarts=2)
    trainer = runner.run(6, failure_hook=failure_hook)
    assert trainer.step == 6
    assert runner.restarts == 1
    assert fired["n"] == 1


def test_fault_runner_gives_up_after_budget(tmp_path):
    run = _run(tmp_path, steps=4, every=1)

    def always_fail(step):
        raise RuntimeError("persistent failure")

    runner = FaultTolerantRunner(lambda: Trainer(run), max_restarts=2)
    with pytest.raises(RuntimeError, match="persistent"):
        runner.run(4, failure_hook=always_fail)
    assert runner.restarts == 3


def test_accum_steps_trajectory_close(tmp_path):
    """accum=2 halves the microbatch but must track the accum=1 trajectory
    (same global batch, fp32 accumulation)."""
    t1 = Trainer(_run(tmp_path / "x", steps=3, accum=1))
    t1.train(3)
    t2 = Trainer(_run(tmp_path / "y", steps=3, accum=2))
    t2.train(3)
    np.testing.assert_allclose(t1.metrics_log[-1]["loss"],
                               t2.metrics_log[-1]["loss"], rtol=1e-3)


def test_trainer_on_named_mesh(tmp_path, single_mesh):
    """Full sharded code path on the 1-device production-named mesh."""
    run = _run(tmp_path, steps=2, every=1)
    t = Trainer(run, mesh=single_mesh)
    t.train(2)
    assert len(t.metrics_log) == 2
    assert np.isfinite(t.metrics_log[-1]["loss"])
