"""Attention implementations are interchangeable: dense == blockwise ==
blockwise_unrolled == flash(interpret); decode ring-cache equals the dense
reference; SWA masks; GQA head mapping."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.registry import get_arch
from repro.models import attention as attn


def _qkv(b=2, s=128, hq=4, hkv=2, d=32):
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, s, hq, d), jnp.float32)
    kk = jax.random.normal(jax.random.fold_in(k, 1), (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (b, s, hkv, d), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    return q, kk, v, pos


@pytest.mark.parametrize("window", [None, 32])
@pytest.mark.parametrize("impl", ["blockwise", "blockwise_unrolled", "flash"])
def test_sdpa_impls_match_dense(impl, window):
    q, k, v, pos = _qkv()
    want = attn.sdpa(q, k, v, pos, pos, causal=True, window=window,
                     impl="dense")
    got = attn.sdpa(q, k, v, pos, pos, causal=True, window=window,
                    impl=impl, chunk=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_swa_masks_distant_tokens():
    """With window w, logits for a query must not depend on keys further than
    w-1 back — perturb a distant key and assert invariance."""
    q, k, v, pos = _qkv(b=1, s=64)
    w = 16
    out1 = attn.sdpa(q, k, v, pos, pos, causal=True, window=w, impl="dense")
    k2 = k.at[:, 10].add(100.0)   # token 10 is > w away from query 63
    v2 = v.at[:, 10].add(100.0)
    out2 = attn.sdpa(q, k2, v2, pos, pos, causal=True, window=w, impl="dense")
    np.testing.assert_allclose(np.asarray(out1[:, 40:]),
                               np.asarray(out2[:, 40:]), rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(out1[:, 10:12]),
                           np.asarray(out2[:, 10:12]))


def test_decode_ring_cache_equals_dense():
    """Feeding tokens one-by-one through decode_attention must equal the full
    dense causal attention at every step (ring buffer, absolute positions)."""
    cfg = dataclasses.replace(get_arch("qwen3-8b").reduced(), num_layers=1)
    p = {}
    from repro.models.layers import init_from_specs

    specs = attn.attention_specs(cfg, jnp.float32)
    p = init_from_specs(specs, jax.random.PRNGKey(0))
    b, s = 1, 24
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    want = attn.self_attention(p, x, cfg, pos, causal=True, impl="dense")
    cache = attn.make_cache(cfg, b, s, jnp.float32)
    got = []
    for t in range(s):
        y, cache = attn.decode_attention(p, x[:, t:t + 1], cfg, cache,
                                         jnp.asarray(t, jnp.int32))
        got.append(y)
    got = jnp.concatenate(got, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_decode_swa_ring_wraparound():
    """SWA cache sized to the window: after wrapping, old tokens must be
    evicted (same result as dense attention with the window mask)."""
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(), num_layers=1)
    w = cfg.sliding_window      # reduced: 64
    from repro.models.layers import init_from_specs

    p = init_from_specs(attn.attention_specs(cfg, jnp.float32),
                        jax.random.PRNGKey(0))
    b, s = 1, 96                # > window so the ring wraps
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32) * 0.1
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    want = attn.self_attention(p, x, cfg, pos, causal=True, impl="dense",
                               window=w)
    cache = attn.make_cache(cfg, b, w, jnp.float32)
    outs = []
    for t in range(s):
        y, cache = attn.decode_attention(p, x[:, t:t + 1], cfg, cache,
                                         jnp.asarray(t, jnp.int32), window=w)
        outs.append(y)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got[:, -8:]),
                               np.asarray(want[:, -8:]), rtol=2e-3, atol=2e-3)


def test_gqa_equals_repeated_mha():
    """GQA with hkv groups must equal MHA with the kv heads explicitly
    repeated."""
    q, k, v, pos = _qkv(hq=8, hkv=2)
    got = attn.sdpa(q, k, v, pos, pos, impl="dense")
    k_rep = jnp.repeat(k, 4, axis=2)
    v_rep = jnp.repeat(v, 4, axis=2)
    want = attn.sdpa(q, k_rep, v_rep, pos, pos, impl="dense")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
