"""First-class docs stay truthful: README/docs exist, and the benchmark
table committed in docs/overlap.md is EXACTLY what benchmarks.docs_sync
renders from the committed BENCH_quick.json (regenerate both with
``python -m benchmarks.run --quick --update-docs``). Fast, non-slow."""
from __future__ import annotations

from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def test_readme_exists_and_covers_the_basics():
    text = (REPO / "README.md").read_text()
    # quickstart commands must mention the tier-1 verify + bench invocations
    assert "python -m pytest -x -q" in text
    assert "python -m benchmarks.run" in text
    # the paper-concept -> module map must point at the real modules
    for mod in ("core/halo.py", "core/domain.py", "core/reduction.py",
                "runtime/trainer.py", "launch/mesh.py"):
        assert mod in text, f"README concept map lost {mod}"


def test_overlap_doc_exists_and_names_the_knobs():
    text = (REPO / "docs" / "overlap.md").read_text()
    for knob in ("two_phase", "hdot", "subdomains", "grad_buckets",
                 "halo_scan_2d", "make_grid_mesh"):
        assert knob in text, f"docs/overlap.md lost {knob}"


def test_bench_table_not_stale():
    """The generated table region must match a fresh render of the committed
    BENCH_quick.json — fails when one is updated without the other."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()
    committed = docs_sync.docs_table()
    assert committed is not None, "docs/overlap.md lost its BENCH_TABLE markers"
    rendered = docs_sync.render_table(quick)
    assert committed == rendered, (
        "docs/overlap.md benchmark table is stale relative to "
        "BENCH_quick.json — run `python -m benchmarks.run --quick "
        "--update-docs` and commit both")


def test_bench_quick_tracks_2d_mesh_rows():
    """The committed trajectory must include `mesh_shape` rows for heat2d and
    hpccg (the 2x2-vs-4x1 overlap gap is tracked from PR 3 onward), the RK3
    (y, z) 2x2 mesh, and HPCCG's native 3-D 2x2x2 mesh (PR 4 onward)."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()

    def meshes(suite):
        return {r.get("mesh_shape") for r in quick[suite]["rows"]
                if "mesh_shape" in r}

    for suite in ("heat2d", "hpccg"):
        assert {"2x2", "4x1"} <= meshes(suite), (suite, meshes(suite))
    assert "2x2" in meshes("creams"), meshes("creams")
    assert "2x2x2" in meshes("hpccg"), meshes("hpccg")


def test_bench_quick_rows_carry_provenance():
    """Every BENCH_quick row records the worker's jax version and device
    count — CI artifacts from different runners are only comparable with
    the toolchain pinned to the row."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()
    for suite, rec in quick.items():
        for r in rec.get("rows", []):
            assert r.get("jax_version"), (suite, r)
            assert r.get("device_count") == r.get("devices"), (suite, r)


def test_render_table_shape():
    from benchmarks import docs_sync

    quick = {"demo": {"rows": [
        {"devices": 4, "mesh_shape": "2x2", "metric": "sweeps_per_s",
         "two_phase": 10.0, "hdot": 8.0, "hdot_two_phase_ratio": 0.8},
        {"devices": 2, "metric": "sweeps_per_s",
         "two_phase": 5.0, "hdot": 5.5, "hdot_two_phase_ratio": 1.1,
         "fsdp": 4.5, "fsdp_two_phase_ratio": 0.9},
    ]}, "mem": {"rows": [
        {"devices": 4, "metric": "peak_live_param_bytes",
         "streaming": 625280.0, "gather_all": 1579904.0,
         "mem_saving_ratio": 2.5266},
    ]}, "broken": {"error": "boom"}}
    table = docs_sync.render_table(quick)
    lines = table.splitlines()
    assert lines[0].startswith("| suite ")
    assert ("| demo | 4 | 2x2 | sweeps_per_s | 10.00 | 8.00 | 0.80x | - |"
            in lines)
    assert ("| demo | 2 | - | sweeps_per_s | 5.00 | 5.50 | 1.10x | 0.90x |"
            in lines)
    assert ("| mem | 4 | - | peak_live_param_bytes | 1579904 | 625280 "
            "| 2.53x | - |" in lines)
    assert any("ERROR" in ln for ln in lines)


def test_bench_quick_tracks_moe_row():
    """The committed trajectory must carry the MoE EP suite (PR 7 onward):
    capacity-chunked a2a_scan (moe_a2a_chunks=2) vs the monolithic
    dispatch/combine, with the headline ratio gated by ci_gate."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()
    rows = quick["moe"]["rows"]
    assert rows, "moe suite lost its rows"
    assert all(r["metric"] == "steps_per_s" for r in rows), rows
    assert "hdot_two_phase_ratio" in quick["moe"]


def test_bench_quick_tracks_serve_row():
    """The committed trajectory must carry the serving suite (PR 8 onward):
    continuous batching (hdot) vs wave scheduling (two_phase) tokens/s on
    the same Poisson trace, with the ratio gated by ci_gate. The benchmark
    itself asserts continuous > wave; the committed row must agree."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()
    rows = quick["serve"]["rows"]
    assert rows, "serve suite lost its rows"
    assert all(r["metric"] == "tokens_per_s" for r in rows), rows
    assert quick["serve"]["hdot_two_phase_ratio"] > 1.0, quick["serve"]


def test_overlap_doc_covers_serving():
    text = (REPO / "docs" / "overlap.md").read_text()
    for ref in ("run_continuous", "decode_step_fn", "build_decode_step",
                "lm_decode_tp"):
        assert ref in text, f"docs/overlap.md lost {ref}"


def test_bench_quick_tracks_fsdp_row():
    """lm_step's committed trajectory must carry the ZeRO-3 composition row
    (PR 5 onward) so the fsdp/two_phase headline is gated by ci_gate."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()
    rows = [r for r in quick["lm_step"]["rows"] if "fsdp_two_phase_ratio" in r]
    assert rows, "lm_step lost its fsdp row"
    assert "fsdp_two_phase_ratio" in quick["lm_step"]


def test_bench_quick_tracks_rebalance_row():
    """The committed trajectory must carry the dynamic re-partitioning drill
    (PR 9 onward): static uniform cut (two_phase slot) vs measured-cost
    re-cut (hdot slot) steps/s under one jax device — the parallelism is OS
    processes. The drill converges near the weighted-balance bound, so the
    committed ratio must show a real recovery, not noise."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()
    rows = quick["rebalance"]["rows"]
    assert rows, "rebalance suite lost its rows"
    assert all(r["metric"] == "steps_per_s" for r in rows), rows
    assert all(r["devices"] == 1 for r in rows), rows
    assert quick["rebalance"]["hdot_two_phase_ratio"] > 1.2, quick["rebalance"]


def test_bench_quick_tracks_fsdp_mem_row():
    """The committed trajectory must carry the streaming ZeRO-3 memory probe
    (PR 10 onward): per-device peak live param bytes, streaming vs the
    top-of-step gather-all, with losses bit-identical and the streaming peak
    within the shard + fsdp_working_set bound. ci_gate fails when the saving
    ratio dips to 1 or below."""
    from benchmarks import docs_sync

    quick = docs_sync.load_quick()
    rows = quick["fsdp_mem"]["rows"]
    assert rows, "fsdp_mem suite lost its rows"
    assert all(r["metric"] == "peak_live_param_bytes" for r in rows), rows
    assert all(r["loss_bit_equal"] for r in rows), rows
    assert all(r["within_working_set_bound"] for r in rows), rows
    assert all(r["streaming"] < r["gather_all"] for r in rows), rows
    assert quick["fsdp_mem"]["mem_saving_ratio"] > 1.0, quick["fsdp_mem"]


def test_overlap_doc_covers_streaming_zero3():
    text = (REPO / "docs" / "overlap.md").read_text()
    for ref in ("fsdp_streaming", "fsdp_working_set", "train_loss_streamed",
                "restore_fsdp_checkpoint", "lm_fsdp_streaming",
                "AG-ADJACENCY", "fsdp_init_state"):
        assert ref in text, f"docs/overlap.md lost {ref}"


def test_overlap_doc_covers_rebalancing():
    text = (REPO / "docs" / "overlap.md").read_text()
    for ref in ("rebalance_every", "chunk_weights", "CostModel",
                "straggler_drill", "heat2d_weighted", "part_extents",
                "reassign_host_shards"):
        assert ref in text, f"docs/overlap.md lost {ref}"
