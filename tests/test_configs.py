"""The 10 assigned architecture configs, frozen against the assignment spec."""
from __future__ import annotations

import pytest

from repro.config.registry import get_arch, list_archs

SPEC = {
    "mixtral-8x7b": dict(L=32, d=4096, H=32, kv=8, V=32000, moe=(8, 2, 14336),
                         swa=True),
    "qwen3-moe-30b-a3b": dict(L=48, d=2048, H=32, kv=4, V=151936,
                              moe=(128, 8, 768)),
    "qwen3-8b": dict(L=36, d=4096, H=32, kv=8, ff=12288, V=151936, qk=True),
    "internlm2-1.8b": dict(L=24, d=2048, H=16, kv=8, ff=8192, V=92544),
    "llama3-405b": dict(L=126, d=16384, H=128, kv=8, ff=53248, V=128256),
    "granite-3-2b": dict(L=40, d=2048, H=32, kv=8, ff=8192, V=49155),
    "llava-next-34b": dict(L=60, d=7168, H=56, kv=8, ff=20480, V=64000),
    "mamba2-780m": dict(L=48, d=1536, V=50280, ssm=128),
    "whisper-base": dict(L=6, d=512, H=8, kv=8, ff=2048, V=51865),
    "recurrentgemma-2b": dict(L=26, d=2560, H=10, kv=1, ff=7680, V=256000),
}


def test_registry_covers_all_assigned():
    assert sorted(list_archs()) == sorted(SPEC)


@pytest.mark.parametrize("arch", sorted(SPEC))
def test_config_matches_assignment(arch):
    s, c = SPEC[arch], get_arch(arch)
    assert c.num_layers == s["L"]
    assert c.d_model == s["d"]
    assert c.vocab_size == s["V"]
    if "H" in s:
        assert (c.num_heads, c.num_kv_heads) == (s["H"], s["kv"])
    if "ff" in s:
        assert c.d_ff == s["ff"]
    if "moe" in s:
        assert (c.moe.num_experts, c.moe.top_k, c.moe.d_ff_expert) == s["moe"]
    if s.get("swa"):
        assert c.sliding_window == 4096
    if s.get("qk"):
        assert c.qk_norm
    if "ssm" in s:
        assert c.ssm.state_dim == s["ssm"]
        assert c.family == "ssm"


def test_subquadratic_flags():
    """long_500k runs exactly for SWA / SSM / hybrid archs."""
    runnable = {a for a in SPEC if get_arch(a).subquadratic}
    assert runnable == {"mixtral-8x7b", "mamba2-780m", "recurrentgemma-2b"}


def test_reduced_configs_stay_in_family():
    for a in SPEC:
        c, r = get_arch(a), get_arch(a).reduced()
        assert r.family == c.family
        assert (r.moe is None) == (c.moe is None)
        assert (r.ssm is None) == (c.ssm is None)
        assert r.num_params() < c.num_params()
