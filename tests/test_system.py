"""End-to-end system behaviour that requires REAL multi-device execution:
run in subprocess workers with forced host device counts (tests themselves
stay single-device). Marked slow — each worker pays jax re-init."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_devices(code: str, devices: int, timeout: int = 600) -> dict:
    """Run `code` (must print one JSON line last) under `devices` devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_heat2d_4dev_matches_1dev_and_schedules():
    code = """
    import json, jax, numpy as np
    from repro.core.stencil import heat2d_init, heat2d_solve
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("data",))
    u0 = heat2d_init(64, 64)
    u_tp, r_tp = heat2d_solve(u0, mesh, "data", 10, mode="two_phase")
    u_hd, r_hd = heat2d_solve(u0, mesh, "data", 10, mode="hdot")
    print(json.dumps({
        "identical": bool(np.allclose(np.asarray(u_tp), np.asarray(u_hd), atol=1e-6)),
        "u_sum": float(np.asarray(u_hd).sum()),
        "residual": float(np.asarray(r_hd)[-1]),
    }))
    """
    multi = run_devices(code, 4)
    single = run_devices(code.replace('make_mesh((4,)', 'make_mesh((1,)'), 1)
    assert multi["identical"] and single["identical"]
    # 4-way decomposition must give the same field as 1 device
    assert multi["u_sum"] == pytest.approx(single["u_sum"], rel=1e-5)
    assert multi["residual"] == pytest.approx(single["residual"], rel=1e-5)


@pytest.mark.slow
def test_collective_matmul_ring_4dev():
    code = """
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.collective_matmul import ag_matmul, matmul_rs
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("model",))
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (64, 32), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (32, 64), jnp.float32)
    outs = {}
    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(ag_matmul, axis_name="model", mode=mode),
            mesh=mesh, in_specs=(P("model", None), P(None, "model")),
            out_specs=P(None, "model")))
        outs[mode] = np.asarray(f(x, w))
    want = np.asarray(x) @ np.asarray(w)
    h = jax.random.normal(k, (64, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (64, 32), jnp.float32)
    zs = {}
    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(matmul_rs, axis_name="model", mode=mode),
            mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
            out_specs=P("model", None)))
        zs[mode] = np.asarray(f(h, v))
    want_z = np.asarray(h) @ np.asarray(v)
    print(json.dumps({
        "ag_ok": bool(np.allclose(outs["hdot"], want, rtol=1e-4, atol=1e-4)),
        "ag_same": bool(np.allclose(outs["hdot"], outs["two_phase"], rtol=1e-5, atol=1e-5)),
        "rs_ok": bool(np.allclose(zs["hdot"], want_z, rtol=1e-4, atol=1e-4)),
        "rs_same": bool(np.allclose(zs["hdot"], zs["two_phase"], rtol=1e-5, atol=1e-5)),
    }))
    """
    r = run_devices(code, 4)
    assert r == {"ag_ok": True, "ag_same": True, "rs_ok": True, "rs_same": True}


@pytest.mark.slow
def test_hierarchical_allreduce_with_compression_8dev():
    """2x4 (pod x data) mesh: staged reduce == plain psum; int8-EF cross-pod
    compression stays within quantization error."""
    code = """
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.reduction import hierarchical_allreduce
    from repro.optim.compression import make_crosspod_codec
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("pod", "data"))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    # the codec shares one scale across the pod axis (pmax) and divides the
    # psum'd scale back out — psum'ing a naive per-pod scale doubles it
    comp, decomp = make_crosspod_codec("pod")

    def staged(x):
        return hierarchical_allreduce(x, "data", "pod", scatter_dim=0)
    def plain(x):
        return jax.lax.psum(x, ("pod", "data"))
    def compressed(x):
        return hierarchical_allreduce(
            x, "data", "pod", scatter_dim=0,
            compress=comp, decompress=decomp)

    outs = {}
    for name, fn in [("staged", staged), ("plain", plain), ("comp", compressed)]:
        f = jax.jit(jax.shard_map(fn, mesh=mesh,
                                  in_specs=P(("pod", "data")), out_specs=P(("pod", "data"))))
        outs[name] = np.asarray(f(jnp.tile(x, (8, 1))))
    err_staged = float(np.abs(outs["staged"] - outs["plain"]).max())
    rel_comp = float(np.abs(outs["comp"] - outs["plain"]).max()
                     / (np.abs(outs["plain"]).max() + 1e-9))
    print(json.dumps({"err_staged": err_staged, "rel_comp": rel_comp}))
    """
    r = run_devices(code, 8)
    assert r["err_staged"] < 1e-4
    assert r["rel_comp"] < 0.03   # int8 quantization of the cross-pod hop


@pytest.mark.slow
def test_mini_production_cell_lowers_on_16dev():
    """A miniature production mesh (4x4, same axis names) lowers+compiles a
    REDUCED arch through the exact dry-run code path (Cell.lower)."""
    code = """
    import json, dataclasses, jax
    from repro.config.registry import get_arch
    from repro.config.shapes import ShapeConfig
    from repro.config.base import ParallelConfig
    from repro.launch.steps import build_cell
    from repro.launch.mesh import make_mesh
    from repro.models.model import ModelOptions
    from repro.analysis.hlo import parse_collectives

    cfg = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("mini_train", seq_len=64, global_batch=8, kind="train")
    cell = build_cell(cfg, shape,
                      ModelOptions(attn_impl="dense", scan_layers=True, remat="none"),
                      ParallelConfig(remat="none"))
    mesh = make_mesh((4, 4), ("data", "model"))
    compiled = cell.lower(mesh).compile()
    coll = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({
        "ok": True,
        "colls": len(coll.ops),
        "arg_mb": mem.argument_size_in_bytes / 1e6,
    }))
    """
    r = run_devices(code, 16)
    assert r["ok"] and r["colls"] > 0


@pytest.mark.slow
def test_grad_sync_pytree_psum_4dev_mixed_dtypes():
    """Zero-copy bucketed sync == monolithic two-phase sync on a REAL 4-way
    reduction with mixed-dtype leaves (integer-valued: sums are exact)."""
    code = """
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.overlap import grad_sync
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("data",))
    k = jax.random.PRNGKey(0)
    tree = {
        "emb": jax.random.randint(k, (16, 8), -4, 5).astype(jnp.bfloat16),
        "w1": jax.random.randint(jax.random.fold_in(k, 1), (33,), -4, 5).astype(jnp.float32),
        "w2": jax.random.randint(jax.random.fold_in(k, 2), (4, 4), -4, 5).astype(jnp.float16),
        "b": jnp.asarray(3.0),
    }
    outs = {}
    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(grad_sync, axes="data", mode=mode, num_buckets=3),
            mesh=mesh, in_specs=(P(),), out_specs=P()))
        outs[mode] = f(tree)
    same = all(bool(np.array_equal(np.asarray(outs["hdot"][k], np.float32),
                                   np.asarray(outs["two_phase"][k], np.float32)))
               for k in tree)
    dtypes_kept = all(outs["hdot"][k].dtype == tree[k].dtype for k in tree)
    scaled = bool(np.array_equal(np.asarray(outs["hdot"]["b"]), 4 * 3.0))
    print(json.dumps({"same": same, "dtypes_kept": dtypes_kept, "scaled": scaled}))
    """
    r = run_devices(code, 4)
    assert r == {"same": True, "dtypes_kept": True, "scaled": True}


@pytest.mark.parametrize("devices", [3, 4])
@pytest.mark.slow
def test_matmul_rs_bidirectional_ring(devices):
    """Bidirectional chunked reduce-scatter ring == psum_scatter, on odd AND
    even mesh sizes (odd rings have asymmetric fwd/bwd path lengths)."""
    code = f"""
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.collective_matmul import matmul_rs
    from repro.launch.mesh import make_mesh
    devices = {devices}
    mesh = make_mesh((devices,), ("model",))
    k = jax.random.PRNGKey(0)
    # s_loc = 15 (odd): bidirectional pieces are UNEVEN, exercising the
    # non-divisor chunk split
    h = jax.random.normal(k, (15 * devices, 8 * devices), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 1), (8 * devices, 16), jnp.float32)
    zs = {{}}
    for mode, chunks in (("two_phase", None), ("hdot", None), ("hdot", 1), ("hdot", 3)):
        f = jax.jit(jax.shard_map(
            functools.partial(matmul_rs, axis_name="model", mode=mode, chunks=chunks),
            mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
            out_specs=P("model", None)))
        zs[f"{{mode}}-{{chunks}}"] = np.asarray(f(h, v))
    want = np.asarray(h) @ np.asarray(v)
    ok = {{name: bool(np.allclose(z, want, rtol=1e-4, atol=1e-4))
          for name, z in zs.items()}}
    print(json.dumps(ok))
    """
    r = run_devices(code, devices)
    assert all(r.values()), r


@pytest.mark.slow
def test_halo_scan_4dev_equals_iterated_apply():
    """Double-buffered halo_scan == iterated stencil_apply across a real
    4-way ring (periodic and Dirichlet)."""
    code = """
    import json, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.halo import halo_scan, stencil_apply
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("data",))
    avg3 = lambda p: (p[:-2] + p[1:-1] + p[2:]) / 3.0
    u = jax.random.normal(jax.random.PRNGKey(0), (64, 5), jnp.float32)
    ok = {}
    for periodic in (False, True):
        got, _ = jax.jit(jax.shard_map(
            lambda x: halo_scan(x, avg3, "data", 1, 0, 6, periodic=periodic),
            mesh=mesh, in_specs=(P("data"),), out_specs=(P("data"), P())))(u)
        def iterate(x):
            for _ in range(6):
                x = stencil_apply(x, avg3, "data", 1, 0, periodic, "hdot")
            return x
        want = jax.jit(jax.shard_map(iterate, mesh=mesh, in_specs=(P("data"),),
                                     out_specs=P("data")))(u)
        ok[str(periodic)] = bool(np.allclose(np.asarray(got), np.asarray(want),
                                             rtol=1e-5, atol=1e-6))
    print(json.dumps(ok))
    """
    r = run_devices(code, 4)
    assert r == {"False": True, "True": True}


@pytest.mark.slow
def test_heat2d_2d_meshes_match_1dev_oracle():
    """2x2 / 4x1 / 1x4 (rows x cols) block decompositions give the SAME field
    and residual history as the 1-device two-phase oracle, both schedules —
    corner correctness included (the corner cells of each shard are computed
    from corner-free face exchanges). Odd shard sizes via a 66x70 grid."""
    code = """
    import json, jax, numpy as np
    from repro.core.stencil import heat2d_init, heat2d_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh
    u0 = heat2d_init(64, 64)
    ref, rres = heat2d_solve(u0, make_mesh((1,), ("data",)), "data", 10,
                             mode="two_phase")
    ok = {}
    for rc in ((2, 2), (4, 1), (1, 4)):
        mesh = make_grid_mesh(*rc)
        for mode in ("two_phase", "hdot"):
            u, res = heat2d_solve(u0, mesh, ("rows", "cols"), 10, mode=mode)
            ok[f"{rc[0]}x{rc[1]}-{mode}"] = bool(
                np.allclose(np.asarray(u), np.asarray(ref), rtol=1e-5, atol=1e-6)
                and np.allclose(np.asarray(res), np.asarray(rres), rtol=1e-4))
    u0b = heat2d_init(66, 70)   # odd 33x35 shards on 2x2
    refb, _ = heat2d_solve(u0b, make_mesh((1,), ("data",)), "data", 7,
                           mode="two_phase")
    ub, _ = heat2d_solve(u0b, make_grid_mesh(2, 2), ("rows", "cols"), 7,
                         mode="hdot")
    ok["odd"] = bool(np.allclose(np.asarray(ub), np.asarray(refb),
                                 rtol=1e-5, atol=1e-6))
    print(json.dumps(ok))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_hpccg_2d_mesh_matches_1dev_oracle():
    """CG on (y, z) 2-D row blocks: the 27-point corner couplings ride the
    sequential two-hop exchange — convergence identical to 1 device."""
    code = """
    import json, jax, jax.numpy as jnp, numpy as np
    from repro.core.stencil import hpccg_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh
    b = jax.random.normal(jax.random.PRNGKey(2), (12, 16, 16), jnp.float32)
    _, href = hpccg_solve(b, make_mesh((1,), ("data",)), "data", 20,
                          mode="two_phase")
    ok = {}
    for rc in ((2, 2), (4, 1), (1, 4)):
        for mode in ("two_phase", "hdot"):
            _, h = hpccg_solve(b, make_grid_mesh(*rc), ("rows", "cols"), 20,
                               mode=mode)
            ok[f"{rc[0]}x{rc[1]}-{mode}"] = bool(
                np.allclose(np.asarray(h), np.asarray(href), rtol=1e-3))
    print(json.dumps(ok))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_heat2d_kernel_sharded_2x2_matches_unsharded():
    """Pallas tile kernel under a 2x2 mesh (exchanged halo ring staged as
    block-edge strips) == the unsharded kernel with the same tile grid."""
    code = """
    import json, jax, jax.numpy as jnp, numpy as np
    from repro.kernels.heat2d import ops as heat_ops
    from repro.launch.mesh import make_grid_mesh
    u = jax.random.normal(jax.random.PRNGKey(0), (64, 64), jnp.float32)
    want = heat_ops.heat2d_sweep(u, tile=(32, 32), sweeps=3, impl="ref")
    got = heat_ops.heat2d_sweep_sharded(u, make_grid_mesh(2, 2),
                                        ("rows", "cols"), tile=(32, 32),
                                        sweeps=3, impl="ref")
    print(json.dumps({"same": bool(np.allclose(np.asarray(got),
                                               np.asarray(want),
                                               rtol=1e-6, atol=1e-6))}))
    """
    r = run_devices(code, 4)
    assert r == {"same": True}


@pytest.mark.slow
def test_rk3_2d_mesh_matches_1dev_oracle():
    """RK3 on (y, z) grid meshes — stage-carried halos on BOTH axes — gives
    the same field as the 1-device two-phase oracle, both schedules (2x2
    exercises the pipelined two-axis path: 32-cell shards >= 4*width)."""
    code = """
    import json, jax, jax.numpy as jnp, numpy as np
    from repro.core.stencil import rk3_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh
    v0 = jax.random.normal(jax.random.PRNGKey(0), (12, 64, 64), jnp.float32)
    ref = rk3_solve(v0, make_mesh((1,), ("data",)), "data", 5, dt=0.01,
                    mode="two_phase")
    ok = {}
    for rc in ((2, 2), (4, 1), (1, 4)):
        for mode in ("two_phase", "hdot"):
            got = rk3_solve(v0, make_grid_mesh(*rc), ("rows", "cols"), 5,
                            dt=0.01, mode=mode)
            ok[f"{rc[0]}x{rc[1]}-{mode}"] = bool(
                np.allclose(np.asarray(got), np.asarray(ref),
                            rtol=2e-5, atol=2e-5))
    print(json.dumps(ok))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_hpccg_3d_mesh_matches_1dev_oracle():
    """CG on HPCCG's native (x, y, z) meshes: ALL the 27-point corner
    couplings — edges and the 8 body corners — ride the chained sequential
    face exchange; convergence identical to 1 device on 2x2x2 and the
    degenerate-axis 4x2x1 / 1x2x4 layouts, with odd per-shard extents
    (12/4=3, 20/4=5, 20/2=10)."""
    code = """
    import json, jax, jax.numpy as jnp, numpy as np
    from repro.core.stencil import hpccg_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh
    b = jax.random.normal(jax.random.PRNGKey(2), (12, 20, 20), jnp.float32)
    _, href = hpccg_solve(b, make_mesh((1,), ("data",)), "data", 20,
                          mode="two_phase")
    ok = {}
    for parts in ((2, 2, 2), (4, 2, 1), (1, 2, 4)):
        for mode in ("two_phase", "hdot"):
            _, h = hpccg_solve(b, make_grid_mesh(*parts),
                               ("planes", "rows", "cols"), 20, mode=mode)
            ok[f"{'x'.join(map(str, parts))}-{mode}"] = bool(
                np.allclose(np.asarray(h), np.asarray(href), rtol=1e-3))
    print(json.dumps(ok))
    """
    r = run_devices(code, 8)
    assert all(r.values()), r


@pytest.mark.slow
def test_halo_scan_nd_peeled_ppermute_count_8dev():
    """3-D halo_scan_nd: one ppermute pair per axis per step, drain peeled.
    Checked through the HLO schedule linter: the canonical `halo3d` target
    (2x2x2 mesh, steps=2) must lint clean — PAIR-COUNT pins 2 pairs * 3
    axes * 2 steps = 12 collective-permutes and DEAD-DRAIN proves every
    exchange's halos reach compute — while the unpeeled mutation must trip
    DEAD-DRAIN (the drain trip's exchange feeds nothing) and PAIR-COUNT
    (one extra pair per axis)."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    rep = lint_target("halo3d")          # PAIR-COUNT expects 2*3*steps,
    broken = lint_target("broken_unpeeled_halo1d")   # DEAD-DRAIN negative
    print(json.dumps({
        "canonical_ok": rep.ok,
        "permute_count_checked": rep.n_collectives == 12,
        "unpeeled_dead_drain": "DEAD-DRAIN" in {f.rule for f in broken.errors},
        "unpeeled_pair_count": "PAIR-COUNT" in {f.rule for f in broken.errors},
    }))
    """
    r = run_devices(code, 8)
    assert all(r.values()), r


@pytest.mark.slow
def test_solver_ppermute_counts_nd():
    """Compiled-solver collective structure on real meshes, via the HLO
    schedule linter: one exchange pair per decomposed axis per step/stage
    (PAIR-COUNT: hpccg_3d 12 permutes, rk3_2d 24), no dead drain exchange
    (DEAD-DRAIN), and every exchange keeps dataflow-independent interior
    compute to fly behind (NO-OVERLAP-WINDOW). The per-target arithmetic
    lives in lint_targets.PERMUTES_* next to the schedule code."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    out = {}
    for name in ("hpccg_3d", "rk3_2d"):
        rep = lint_target(name)   # PAIR-COUNT pins 12 / 24 permutes,
        out[name] = {"ok": rep.ok,            # DEAD-DRAIN pins no drain
                     "errors": sorted({f.rule for f in rep.errors})}
    print(json.dumps(out))
    """
    r = run_devices(code, 8)
    assert all(v["ok"] for v in r.values()), r


@pytest.mark.slow
def test_fsdp_trainer_4dev_matches_replicated_and_two_phase():
    """The ZeRO-3 oracle: param_shard=True on a real 4-way DP mesh produces
    the SAME losses, params and optimizer moments as the replicated explicit
    hdot step and the two-phase baseline (the same sums, reduce-scattered
    instead of all-reduced; tolerances only absorb f32 summation-order
    freedom in the grad-norm partials), while per-device parameter and
    optimizer residency is EXACTLY 1/4 of the padded flat state — asserted
    by buffer-shape inspection of the committed shards."""
    code = """
    import json, jax, jax.numpy as jnp, numpy as np
    from repro.config.base import ParallelConfig, RunConfig, TrainConfig
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.runtime.trainer import Trainer

    cfg = get_arch("qwen3-8b").reduced()
    train = TrainConfig(global_batch=8, seq_len=32, warmup_steps=2,
                        total_steps=10, checkpoint_every=10**6,
                        checkpoint_dir="/tmp/repro_fsdp_oracle")
    mesh = make_mesh((4,), ("data",))
    runs = {
        "fsdp": ParallelConfig(param_shard=True, remat="none"),
        "repl": ParallelConfig(param_shard=False, remat="none"),
        "two_phase": ParallelConfig(param_shard=False, overlap="two_phase",
                                    remat="none"),
    }
    state, out = {}, {}
    for name, par in runs.items():
        t = Trainer(RunConfig(cfg, par, train), mesh=mesh)
        t.train(3)
        state[name] = t
    def leaves32(tree):
        return [np.asarray(l, np.float32) for l in jax.tree.leaves(tree)]
    f, r, tp = state["fsdp"], state["repl"], state["two_phase"]
    lf = [m["loss"] for m in f.metrics_log]
    out["losses_equal"] = (
        np.allclose(lf, [m["loss"] for m in r.metrics_log], rtol=1e-6)
        and np.allclose(lf, [m["loss"] for m in tp.metrics_log], rtol=1e-6))
    # vs the replicated hdot step: same per-leaf reduction dtypes, so the
    # only float-order freedom is the grad-norm partial sums (~1e-7 rel)
    out["params_match_repl"] = all(
        np.allclose(a, b, rtol=1e-5, atol=1e-6)
        for a, b in zip(leaves32(f.full_params()), leaves32(r.params)))
    # vs two_phase: its monolithic concat upcasts bf16 grads to f32 before
    # the reduce, so bf16 weights may differ by an ulp after 3 updates
    out["params_match_two_phase"] = all(
        np.allclose(a, c, rtol=1e-2, atol=1e-3)
        for a, c in zip(leaves32(f.full_params()), leaves32(tp.params)))
    # optimizer moments: reassemble the flat f32 shard buffers leaf-wise
    from repro.core.overlap import fsdp_unshard_full
    m_f = fsdp_unshard_full(f.opt_state["m"], f._fsdp_layout)
    out["moments_match"] = all(
        np.allclose(a, b, rtol=1e-5, atol=1e-7)
        for a, b in zip(leaves32(m_f), leaves32(r.opt_state["m"])))
    # residency: each committed shard holds exactly padded/4 elements
    layout = f._fsdp_layout
    def dev_bytes(tree):
        return sum(l.addressable_shards[0].data.size
                   * l.addressable_shards[0].data.dtype.itemsize
                   for l in jax.tree.leaves(tree))
    out["param_shard_bytes_exact"] = dev_bytes(f.params) == layout.shard_bytes()
    full_bytes = sum(
        g.padded * jnp.dtype(g.dtype).itemsize for g in layout.groups)
    out["param_residency_quarter"] = dev_bytes(f.params) * 4 == full_bytes
    mv = {"m": f.opt_state["m"], "v": f.opt_state["v"]}
    full_f32 = sum(g.padded for g in layout.groups) * 4
    out["opt_residency_quarter"] = dev_bytes(mv) * 4 == 2 * full_f32
    print(json.dumps(out))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_fsdp_step_hlo_one_rs_one_ag_per_bucket_reverse_emission():
    """Collective structure of the compiled ZeRO-3 step on 4 devices: exactly
    ONE reduce-scatter and ONE all-gather per flat bucket buffer, each
    scatter output shard-sized (grad residency leaves the program at 1/4),
    all-gathers EMITTED in forward bucket order and reduce-scatters in
    REVERSE — the last-backward bucket's collective enters the program
    first, before every earlier bucket's, which is the priority order XLA's
    latency-hiding scheduler launches them in while the remaining backward
    still computes. Emission order is read off channel_id, which jax assigns
    in trace order (the scheduled text order is backend-dependent)."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    # ONE-RS-ONE-AG pins one shard-sized RS + one full-sized AG per bucket
    # buffer, BUCKET-ORDER pins reverse-topo RS / forward AG emission, and
    # DONATION-LOST pins the donated state aliasing; expectations come from
    # fsdp_layout_for itself (see lint_targets).
    rep = lint_target("lm_fsdp_1d")
    broken = lint_target("broken_double_gather_fsdp")
    print(json.dumps({
        "canonical_ok": rep.ok,
        "double_gather_caught":
            "ONE-RS-ONE-AG" in {f.rule for f in broken.errors},
    }))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_fsdp_streaming_4dev_bit_identical_and_shard_residency():
    """The tentpole contract on a real 4-way DP mesh: streaming ZeRO-3
    (per-layer gather + backward regather) is BIT-identical to the gather-all
    step — losses, params, AdamW moments — while persistent per-device
    parameter residency is exactly layout.shard_bytes() (the gathered
    working set is transient, it never lands in the carried state)."""
    code = """
    import json, tempfile
    import jax, numpy as np
    from repro.config.base import ParallelConfig, RunConfig, TrainConfig
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.models.model import ModelOptions
    from repro.runtime.trainer import Trainer
    cfg = get_arch("qwen3-8b").reduced()
    train = TrainConfig(global_batch=4, seq_len=16, warmup_steps=2,
                        total_steps=8, checkpoint_every=10**6,
                        checkpoint_dir=tempfile.mkdtemp())
    mesh = make_mesh((4,), ("data",))
    # matched options: unfused xent (the streamed loss uses log_softmax) and
    # full remat on both sides, so the two programs are numerically the same
    opts = ModelOptions(attn_impl="dense", scan_layers=False, remat="full",
                        fused_xent=False)
    trainers = {}
    for name, par in {
        "stream": ParallelConfig(param_shard=True, fsdp_streaming=True,
                                 scan_layers=False, remat="full"),
        "gather": ParallelConfig(param_shard=True, scan_layers=False,
                                 remat="full", bucket_order="layer"),
    }.items():
        t = Trainer(RunConfig(cfg, par, train), mesh=mesh, options=opts)
        t.train(2)
        trainers[name] = t
    s, g = trainers["stream"], trainers["gather"]
    out = {
        "losses_bit_equal": [m["loss"] for m in s.metrics_log]
                            == [m["loss"] for m in g.metrics_log],
        "params_bit_equal": all(
            np.array_equal(np.asarray(s.params[k], np.float32),
                           np.asarray(g.params[k], np.float32))
            for k in s.params),
        "moments_bit_equal": all(
            np.array_equal(np.asarray(s.opt_state[mom][k]),
                           np.asarray(g.opt_state[mom][k]))
            for mom in ("m", "v") for k in s.params),
    }
    dev_bytes = sum(l.addressable_shards[0].data.size
                    * l.addressable_shards[0].data.dtype.itemsize
                    for l in jax.tree.leaves(s.params))
    out["param_residency_is_shard"] = (
        dev_bytes == s._fsdp_layout.shard_bytes())
    print(json.dumps(out))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_fsdp_streaming_step_hlo_per_layer_gather_adjacency():
    """Streaming ZeRO-3 lint on 4 devices: the per-layer schedule gathers
    each bucket at its consuming layer (forward order), REGATHERS layer
    buckets inside their remat regions last-backward-first, and keeps at
    most fsdp_working_set gathered buffers live at once — all green with
    zero exposed collectives. The gather-all mutation on the SAME layout
    (its ctx expectations match its own emission) must trip exactly
    AG-ADJACENCY: every gathered weight survives to its backward consumer,
    so all buckets' buffers are live simultaneously."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    rep = lint_target("lm_fsdp_streaming")
    broken = lint_target("broken_gather_all_streaming")
    rules = {f.rule for f in broken.errors}
    print(json.dumps({
        "canonical_ok": rep.ok,
        "gather_all_caught": "AG-ADJACENCY" in rules,
        "gather_all_trips_only_adjacency": rules == {"AG-ADJACENCY"},
    }))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_grad_sync_reverse_topo_emission_order_4dev():
    """The replicated explicit schedule with layer provenance: per-bucket
    psums are EMITTED last-backward-first. channel_id records trace order,
    so the deepest bucket's all-reduce must carry the lowest channel id —
    with order='tree' the same buckets are emitted shallowest-first."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    # BUCKET-ORDER compares channel-id order against make_buckets' own
    # emission sequence ([53, 37, 23, 11] for reverse_topo on the fixture
    # tree); the tree-order mutation must trip exactly that rule.
    rep = lint_target("grad_sync_1d")
    broken = lint_target("broken_tree_grad_sync")
    print(json.dumps({
        "canonical_ok": rep.ok,
        "tree_order_caught":
            "BUCKET-ORDER" in {f.rule for f in broken.errors},
    }))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_halo_scan_peeled_ppermute_count_4dev():
    """The drain-step peel drops one ppermute pair per solve, proven by the
    HLO schedule linter: the canonical 1-D and 2-D halo scans lint clean
    (PAIR-COUNT pins 2*axes*steps permutes, DEAD-DRAIN proves every halo is
    consumed), the unpeeled mutation trips DEAD-DRAIN (the drain exchange's
    result feeds nothing — XLA would reap it only when unrolled; the
    production while-loop lowering executes it) plus PAIR-COUNT, and the
    donation mutation (jit without donate_argnums) trips DONATION-LOST."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    out = {}
    for name in ("halo1d", "halo2d"):   # PAIR-COUNT pins 2*axes*steps
        rep = lint_target(name)
        out[name + "_ok"] = rep.ok
    broken = lint_target("broken_unpeeled_halo1d")
    rules = {f.rule for f in broken.errors}
    out["unpeeled_dead_drain"] = "DEAD-DRAIN" in rules
    out["unpeeled_extra_pair"] = "PAIR-COUNT" in rules
    nodon = lint_target("broken_no_donate_halo1d")
    out["no_donate_caught"] = (
        "DONATION-LOST" in {f.rule for f in nodon.errors})
    print(json.dumps(out))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r
