"""End-to-end system behaviour that requires REAL multi-device execution:
run in subprocess workers with forced host device counts (tests themselves
stay single-device). Marked slow — each worker pays jax re-init."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

REPO = Path(__file__).resolve().parents[1]


def run_devices(code: str, devices: int, timeout: int = 600) -> dict:
    """Run `code` (must print one JSON line last) under `devices` devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_heat2d_4dev_matches_1dev_and_schedules():
    code = """
    import json, jax, numpy as np
    from repro.core.stencil import heat2d_init, heat2d_solve
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("data",))
    u0 = heat2d_init(64, 64)
    u_tp, r_tp = heat2d_solve(u0, mesh, "data", 10, mode="two_phase")
    u_hd, r_hd = heat2d_solve(u0, mesh, "data", 10, mode="hdot")
    print(json.dumps({
        "identical": bool(np.allclose(np.asarray(u_tp), np.asarray(u_hd), atol=1e-6)),
        "u_sum": float(np.asarray(u_hd).sum()),
        "residual": float(np.asarray(r_hd)[-1]),
    }))
    """
    multi = run_devices(code, 4)
    single = run_devices(code.replace('make_mesh((4,)', 'make_mesh((1,)'), 1)
    assert multi["identical"] and single["identical"]
    # 4-way decomposition must give the same field as 1 device
    assert multi["u_sum"] == pytest.approx(single["u_sum"], rel=1e-5)
    assert multi["residual"] == pytest.approx(single["residual"], rel=1e-5)


@pytest.mark.slow
def test_collective_matmul_ring_4dev():
    code = """
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.collective_matmul import ag_matmul, matmul_rs
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("model",))
    k = jax.random.PRNGKey(0)
    x = jax.random.normal(k, (64, 32), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(k, 1), (32, 64), jnp.float32)
    outs = {}
    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(ag_matmul, axis_name="model", mode=mode),
            mesh=mesh, in_specs=(P("model", None), P(None, "model")),
            out_specs=P(None, "model")))
        outs[mode] = np.asarray(f(x, w))
    want = np.asarray(x) @ np.asarray(w)
    h = jax.random.normal(k, (64, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 2), (64, 32), jnp.float32)
    zs = {}
    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(matmul_rs, axis_name="model", mode=mode),
            mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
            out_specs=P("model", None)))
        zs[mode] = np.asarray(f(h, v))
    want_z = np.asarray(h) @ np.asarray(v)
    print(json.dumps({
        "ag_ok": bool(np.allclose(outs["hdot"], want, rtol=1e-4, atol=1e-4)),
        "ag_same": bool(np.allclose(outs["hdot"], outs["two_phase"], rtol=1e-5, atol=1e-5)),
        "rs_ok": bool(np.allclose(zs["hdot"], want_z, rtol=1e-4, atol=1e-4)),
        "rs_same": bool(np.allclose(zs["hdot"], zs["two_phase"], rtol=1e-5, atol=1e-5)),
    }))
    """
    r = run_devices(code, 4)
    assert r == {"ag_ok": True, "ag_same": True, "rs_ok": True, "rs_same": True}


@pytest.mark.slow
def test_hierarchical_allreduce_with_compression_8dev():
    """2x4 (pod x data) mesh: staged reduce == plain psum; int8-EF cross-pod
    compression stays within quantization error."""
    code = """
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.reduction import hierarchical_allreduce
    from repro.optim.compression import make_crosspod_codec
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((2, 4), ("pod", "data"))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64), jnp.float32)
    # the codec shares one scale across the pod axis (pmax) and divides the
    # psum'd scale back out — psum'ing a naive per-pod scale doubles it
    comp, decomp = make_crosspod_codec("pod")

    def staged(x):
        return hierarchical_allreduce(x, "data", "pod", scatter_dim=0)
    def plain(x):
        return jax.lax.psum(x, ("pod", "data"))
    def compressed(x):
        return hierarchical_allreduce(
            x, "data", "pod", scatter_dim=0,
            compress=comp, decompress=decomp)

    outs = {}
    for name, fn in [("staged", staged), ("plain", plain), ("comp", compressed)]:
        f = jax.jit(jax.shard_map(fn, mesh=mesh,
                                  in_specs=P(("pod", "data")), out_specs=P(("pod", "data"))))
        outs[name] = np.asarray(f(jnp.tile(x, (8, 1))))
    err_staged = float(np.abs(outs["staged"] - outs["plain"]).max())
    rel_comp = float(np.abs(outs["comp"] - outs["plain"]).max()
                     / (np.abs(outs["plain"]).max() + 1e-9))
    print(json.dumps({"err_staged": err_staged, "rel_comp": rel_comp}))
    """
    r = run_devices(code, 8)
    assert r["err_staged"] < 1e-4
    assert r["rel_comp"] < 0.03   # int8 quantization of the cross-pod hop


@pytest.mark.slow
def test_mini_production_cell_lowers_on_16dev():
    """A miniature production mesh (4x4, same axis names) lowers+compiles a
    REDUCED arch through the exact dry-run code path (Cell.lower)."""
    code = """
    import json, dataclasses, jax
    from repro.config.registry import get_arch
    from repro.config.shapes import ShapeConfig
    from repro.config.base import ParallelConfig
    from repro.launch.steps import build_cell
    from repro.launch.mesh import make_mesh
    from repro.models.model import ModelOptions
    from repro.analysis.hlo import parse_collectives

    cfg = get_arch("qwen3-8b").reduced()
    shape = ShapeConfig("mini_train", seq_len=64, global_batch=8, kind="train")
    cell = build_cell(cfg, shape,
                      ModelOptions(attn_impl="dense", scan_layers=True, remat="none"),
                      ParallelConfig(remat="none"))
    mesh = make_mesh((4, 4), ("data", "model"))
    compiled = cell.lower(mesh).compile()
    coll = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    print(json.dumps({
        "ok": True,
        "colls": len(coll.ops),
        "arg_mb": mem.argument_size_in_bytes / 1e6,
    }))
    """
    r = run_devices(code, 16)
    assert r["ok"] and r["colls"] > 0


@pytest.mark.slow
def test_grad_sync_pytree_psum_4dev_mixed_dtypes():
    """Zero-copy bucketed sync == monolithic two-phase sync on a REAL 4-way
    reduction with mixed-dtype leaves (integer-valued: sums are exact)."""
    code = """
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.overlap import grad_sync
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("data",))
    k = jax.random.PRNGKey(0)
    tree = {
        "emb": jax.random.randint(k, (16, 8), -4, 5).astype(jnp.bfloat16),
        "w1": jax.random.randint(jax.random.fold_in(k, 1), (33,), -4, 5).astype(jnp.float32),
        "w2": jax.random.randint(jax.random.fold_in(k, 2), (4, 4), -4, 5).astype(jnp.float16),
        "b": jnp.asarray(3.0),
    }
    outs = {}
    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(grad_sync, axes="data", mode=mode, num_buckets=3),
            mesh=mesh, in_specs=(P(),), out_specs=P()))
        outs[mode] = f(tree)
    same = all(bool(np.array_equal(np.asarray(outs["hdot"][k], np.float32),
                                   np.asarray(outs["two_phase"][k], np.float32)))
               for k in tree)
    dtypes_kept = all(outs["hdot"][k].dtype == tree[k].dtype for k in tree)
    scaled = bool(np.array_equal(np.asarray(outs["hdot"]["b"]), 4 * 3.0))
    print(json.dumps({"same": same, "dtypes_kept": dtypes_kept, "scaled": scaled}))
    """
    r = run_devices(code, 4)
    assert r == {"same": True, "dtypes_kept": True, "scaled": True}


@pytest.mark.parametrize("devices", [3, 4])
@pytest.mark.slow
def test_matmul_rs_bidirectional_ring(devices):
    """Bidirectional chunked reduce-scatter ring == psum_scatter, on odd AND
    even mesh sizes (odd rings have asymmetric fwd/bwd path lengths)."""
    code = f"""
    import json, functools, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.collective_matmul import matmul_rs
    from repro.launch.mesh import make_mesh
    devices = {devices}
    mesh = make_mesh((devices,), ("model",))
    k = jax.random.PRNGKey(0)
    # s_loc = 15 (odd): bidirectional pieces are UNEVEN, exercising the
    # non-divisor chunk split
    h = jax.random.normal(k, (15 * devices, 8 * devices), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(k, 1), (8 * devices, 16), jnp.float32)
    zs = {{}}
    for mode, chunks in (("two_phase", None), ("hdot", None), ("hdot", 1), ("hdot", 3)):
        f = jax.jit(jax.shard_map(
            functools.partial(matmul_rs, axis_name="model", mode=mode, chunks=chunks),
            mesh=mesh, in_specs=(P(None, "model"), P("model", None)),
            out_specs=P("model", None)))
        zs[f"{{mode}}-{{chunks}}"] = np.asarray(f(h, v))
    want = np.asarray(h) @ np.asarray(v)
    ok = {{name: bool(np.allclose(z, want, rtol=1e-4, atol=1e-4))
          for name, z in zs.items()}}
    print(json.dumps(ok))
    """
    r = run_devices(code, devices)
    assert all(r.values()), r


@pytest.mark.slow
def test_halo_scan_4dev_equals_iterated_apply():
    """Double-buffered halo_scan == iterated stencil_apply across a real
    4-way ring (periodic and Dirichlet)."""
    code = """
    import json, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core.halo import halo_scan, stencil_apply
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("data",))
    avg3 = lambda p: (p[:-2] + p[1:-1] + p[2:]) / 3.0
    u = jax.random.normal(jax.random.PRNGKey(0), (64, 5), jnp.float32)
    ok = {}
    for periodic in (False, True):
        got, _ = jax.jit(jax.shard_map(
            lambda x: halo_scan(x, avg3, "data", 1, 0, 6, periodic=periodic),
            mesh=mesh, in_specs=(P("data"),), out_specs=(P("data"), P())))(u)
        def iterate(x):
            for _ in range(6):
                x = stencil_apply(x, avg3, "data", 1, 0, periodic, "hdot")
            return x
        want = jax.jit(jax.shard_map(iterate, mesh=mesh, in_specs=(P("data"),),
                                     out_specs=P("data")))(u)
        ok[str(periodic)] = bool(np.allclose(np.asarray(got), np.asarray(want),
                                             rtol=1e-5, atol=1e-6))
    print(json.dumps(ok))
    """
    r = run_devices(code, 4)
    assert r == {"False": True, "True": True}
