"""Launch layer: mesh factories, input specs, cell construction (1-device)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.base import ParallelConfig
from repro.config.registry import get_arch, list_archs
from repro.config.shapes import cell_is_runnable, shape_by_name
from repro.launch.steps import build_cell
from repro.models.model import ModelOptions, input_specs


def test_mesh_factories_single_device(single_mesh):
    from repro.launch.mesh import describe, mesh_axis_size

    assert single_mesh.devices.size == 1
    assert mesh_axis_size(single_mesh, "data") == 1
    assert mesh_axis_size(single_mesh, "pod") == 1  # absent -> 1
    assert "data=1" in describe(single_mesh)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("shape_name", ["train_4k", "decode_32k"])
def test_input_specs_complete(arch, shape_name):
    """Every runnable cell produces spec/axes trees of identical structure
    and only ShapeDtypeStruct leaves — the dry-run contract."""
    cfg = get_arch(arch)
    shape = shape_by_name(shape_name)
    if not cell_is_runnable(cfg.subquadratic, shape):
        pytest.skip("documented long_500k skip")
    io = input_specs(cfg, shape, ModelOptions(scan_layers=True))
    specs, axes = io["specs"], io["axes"]
    s_leaves = jax.tree.leaves(specs)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in s_leaves)
    a_leaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    assert len(jax.tree.leaves(
        jax.tree.map(lambda s, a: len(s.shape) == len(a), specs, axes,
                     is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))))


def test_cell_smoke_runs_on_single_device(single_mesh):
    """A reduced train cell built through the dry-run code path actually
    EXECUTES (not just lowers) on the 1-device production-named mesh."""
    import dataclasses

    from repro.config.shapes import ShapeConfig

    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(),
                              num_layers=2)
    shape = ShapeConfig("mini", seq_len=32, global_batch=2, kind="train")
    cell = build_cell(cfg, shape,
                      ModelOptions(attn_impl="dense", scan_layers=True,
                                   remat="none"),
                      ParallelConfig(remat="none"))
    compiled = cell.lower(single_mesh).compile()
    # materialize real args and execute one step
    model = cell.model
    params = model.init(jax.random.PRNGKey(0))
    from repro.optim import adamw_init

    opt = adamw_init(params)
    batch = {"tokens": jnp.ones((2, 32), jnp.int32),
             "targets": jnp.ones((2, 32), jnp.int32)}
    out = compiled(params, opt, batch)
    p2, o2, metrics = out
    assert np.isfinite(float(metrics["loss"]))
    assert int(o2["step"]) == 1


def test_rules_recipe_selection():
    from repro.sharding.rules import DEFAULT_RULES, SERVE_RULES, rules_for

    assert rules_for("train") == dict(DEFAULT_RULES)
    assert rules_for("prefill") == dict(DEFAULT_RULES)
    assert rules_for("decode") == dict(SERVE_RULES)
    assert rules_for("decode")["batch"] == [("pod",), None]


def test_serve_recipe_resolves_full_tp():
    """Decode recipe shards weights over (model x data) when divisible."""
    import numpy as np

    from jax.sharding import PartitionSpec as P

    from repro.sharding.rules import ShardingContext, resolve_pspec, rules_for

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), object)

    ctx = ShardingContext(FakeMesh(), rules_for("decode"))  # type: ignore
    # mlp weight (d, ff): d -> data, ff -> model (data used)
    assert resolve_pspec((2048, 8192), ("embed", "mlp"), ctx) == P("data", "model")
    # KV cache seq dim takes (model, data) jointly
    spec = resolve_pspec((128, 32768, 8, 64),
                         ("batch", "kv_seq", "act_kv_heads", None), ctx)
    assert spec[1] == ("model", "data")
