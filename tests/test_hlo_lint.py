"""HLO schedule linter: parser + rule engine on synthetic HLO text (fast),
plus subprocess mutation tests that lower the BROKEN lint targets on real
forced-device meshes and assert each schedule regression trips exactly its
rule. The canonical-target PASS assertions live in test_system.py next to
the behaviours they guard."""
from __future__ import annotations

import json

import pytest

from repro.analysis.hlo_ir import (is_compute, parse_hlo_module,
                                   reaches_live_compute)
from repro.analysis.hlo_lint import lint_text
from repro.analysis.rules import ALL_RULES, RULES_BY_ID, LintContext

from tests.test_system import run_devices


def _module(body: str, header_attrs: str = "") -> str:
    head = "HloModule synthetic" + (", " + header_attrs if header_attrs else "")
    return head + "\n\nENTRY main {\n" + body + "\n}\n"


def _rules(report):
    return sorted({f.rule for f in report.findings})


# ------------------------------------------------------------------ parser
def test_parser_instructions_channels_and_root():
    txt = _module("""\
  p0 = f32[16] parameter(0)
  cp = f32[16] collective-permute(p0), channel_id=3, source_target_pairs={{0,1},{1,0}}
  m = f32[16] multiply(p0, p0)
  ROOT r = f32[16] add(m, cp)""")
    mod = parse_hlo_module(txt)
    assert mod.entry is not None and mod.entry.name == "main"
    ops = mod.entry.by_name
    assert set(ops) == {"p0", "cp", "m", "r"}
    assert ops["cp"].channel_id == 3
    assert ops["cp"].source_target_pairs == ((0, 1), (1, 0))
    assert ops["r"].is_root and not ops["m"].is_root
    assert ops["cp"].elements() == 16
    assert ops["r"].operands == ("m", "cp")
    assert is_compute(mod, ops["m"]) and not is_compute(mod, ops["cp"])


def test_parser_strips_position_comments():
    # HLO interleaves /*index=N*/ comments into long operand lists; the
    # parser must still see the instruction (this broke call-site parsing
    # for >=6-element tuples before the comment strip)
    txt = _module("""\
  p0 = f32[16] parameter(0)
  t = (f32[16], /*index=1*/f32[16]) tuple(p0, /*index=1*/p0)
  ROOT g = f32[16] get-tuple-element(t), index=1""")
    mod = parse_hlo_module(txt)
    assert set(mod.entry.by_name) == {"p0", "t", "g"}
    assert mod.entry.by_name["g"].tuple_index == 1


def test_taint_follows_call_and_tuple_elements():
    # value rides a call's result tuple: element 0 reaches compute at the
    # call site, element 1 is dropped — only the first permute is live
    txt = """HloModule taint

callee {
  cp.1 = f32[16] parameter(0)
  cp.2 = f32[16] parameter(1)
  ROOT out = (f32[16], f32[16]) tuple(cp.1, cp.2)
}

ENTRY main {
  p0 = f32[16] parameter(0)
  live = f32[16] collective-permute(p0), channel_id=1, source_target_pairs={{0,1},{1,0}}
  dead = f32[16] collective-permute(p0), channel_id=2, source_target_pairs={{0,1},{1,0}}
  c = (f32[16], f32[16]) call(live, dead), to_apply=callee
  keep = f32[16] get-tuple-element(c), index=0
  ROOT r = f32[16] add(keep, keep)
}
"""
    mod = parse_hlo_module(txt)
    comp = mod.entry
    assert reaches_live_compute(mod, comp, comp.by_name["live"])
    assert not reaches_live_compute(mod, comp, comp.by_name["dead"])


# ------------------------------------------------------------------- rules
def test_registry_is_complete():
    ids = [r.id for r in ALL_RULES]
    assert len(ids) == len(set(ids)) == 8
    assert set(RULES_BY_ID) == set(ids)
    for r in ALL_RULES:
        assert r.fix_hint and (r.__doc__ or "").strip()


def test_dead_drain_fires_on_unconsumed_permute():
    txt = _module("""\
  p0 = f32[16] parameter(0)
  drain = f32[16] collective-permute(p0), channel_id=1, source_target_pairs={{0,1},{1,0}}
  ROOT r = f32[16] add(p0, p0)""")
    rep = lint_text(txt, LintContext(), target="synthetic")
    assert "DEAD-DRAIN" in _rules(rep) and not rep.ok
    # consumed by compute: clean
    txt = _module("""\
  p0 = f32[16] parameter(0)
  cp = f32[16] collective-permute(p0), channel_id=1, source_target_pairs={{0,1},{1,0}}
  ROOT r = f32[16] add(cp, p0)""")
    assert "DEAD-DRAIN" not in _rules(lint_text(txt, LintContext()))


def test_pair_count_total_and_balance():
    ring = "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}"
    rev = "source_target_pairs={{1,0},{2,1},{3,2},{0,3}}"
    body = f"""\
  p0 = f32[16] parameter(0)
  p1 = f32[16] parameter(1)
  fwd = f32[16] collective-permute(p0), channel_id=1, {ring}
  bwd = f32[16] collective-permute(p0), channel_id=2, {rev}
  interior = f32[16] multiply(p1, p1)
  s = f32[16] add(fwd, bwd)
  ROOT r = f32[16] add(s, interior)"""
    ok = lint_text(_module(body),
                   LintContext(expected_permute_total=2))
    assert ok.ok, ok.render()
    wrong_total = lint_text(_module(body),
                            LintContext(expected_permute_total=4))
    assert "PAIR-COUNT" in _rules(wrong_total)
    # a forward shift without its reverse is a lost halo
    unbalanced = _module(f"""\
  p0 = f32[16] parameter(0)
  fwd = f32[16] collective-permute(p0), channel_id=1, {ring}
  ROOT r = f32[16] add(fwd, p0)""")
    rep = lint_text(unbalanced, LintContext(expected_permute_total=1))
    assert "PAIR-COUNT" in _rules(rep)
    assert any("reverse" in f.message for f in rep.findings)


def test_pair_count_a2a_total():
    """expected_a2a_total pins the MoE EP dispatch/combine count (2Q per
    traced layer body per direction); a2a is its own transpose so there is
    no fwd/bwd ring balance to check."""
    body = """\
  p0 = f32[16] parameter(0)
  p1 = f32[16] parameter(1)
  dispatch = f32[16] all-to-all(p0), channel_id=1, replica_groups={{0,1}}
  expert = f32[16] multiply(dispatch, dispatch)
  combine = f32[16] all-to-all(expert), channel_id=2, replica_groups={{0,1}}
  interior = f32[16] multiply(p1, p1)
  ROOT r = f32[16] add(combine, interior)"""
    ok = lint_text(_module(body), LintContext(expected_a2a_total=2))
    assert ok.ok, ok.render()
    wrong = lint_text(_module(body), LintContext(expected_a2a_total=4))
    rep_rules = _rules(wrong)
    assert "PAIR-COUNT" in rep_rules
    assert any("all-to-alls" in f.message for f in wrong.findings)


def test_bucket_order_reads_channel_ids():
    body = """\
  p0 = f32[23] parameter(0)
  p1 = f32[11] parameter(1)
  ar1 = f32[11] all-reduce(p1), channel_id=1, to_apply=add_f32
  ar2 = f32[23] all-reduce(p0), channel_id=2, to_apply=add_f32
  ROOT t = (f32[11], f32[23]) tuple(ar1, ar2)"""
    good = lint_text(_module(body),
                     LintContext(expected_ar_elements=[11, 23]))
    assert "BUCKET-ORDER" not in _rules(good)
    bad = lint_text(_module(body),
                    LintContext(expected_ar_elements=[23, 11]))
    assert "BUCKET-ORDER" in _rules(bad)


def test_one_rs_one_ag_multiset():
    body = """\
  p0 = f32[32] parameter(0)
  ag1 = f32[32] all-gather(p0), channel_id=1, dimensions={0}
  ag2 = f32[32] all-gather(p0), channel_id=2, dimensions={0}
  ROOT t = (f32[32], f32[32]) tuple(ag1, ag2)"""
    dup = lint_text(_module(body),
                    LintContext(expected_ag_elements=[32]))
    assert "ONE-RS-ONE-AG" in _rules(dup)
    assert any("surplus" in f.message for f in dup.findings)
    missing = lint_text(_module(body),
                        LintContext(expected_ag_elements=[32, 32, 64]))
    assert any("missing" in f.message for f in missing.findings)
    exact = lint_text(_module(body),
                      LintContext(expected_ag_elements=[32, 32]))
    assert "ONE-RS-ONE-AG" not in _rules(exact)


def test_wire_widen_compares_dtype_budgets():
    body = """\
  p0 = f32[100] parameter(0)
  ar = f32[100] all-reduce(p0), channel_id=1, to_apply=add_f32
  ROOT r = f32[100] add(ar, p0)"""
    widened = lint_text(_module(body),
                        LintContext(wire_dtype_elements={"bf16": 100}))
    assert "WIRE-WIDEN" in _rules(widened)
    assert any("bf16" in f.message for f in widened.findings)
    at_width = lint_text(_module(body),
                         LintContext(wire_dtype_elements={"f32": 100}))
    assert "WIRE-WIDEN" not in _rules(at_width)


def test_no_overlap_window_needs_independent_compute():
    serial = _module("""\
  p0 = f32[16] parameter(0)
  cp = f32[16] collective-permute(p0), channel_id=1, source_target_pairs={{0,1},{1,0}}
  ROOT r = f32[16] add(cp, p0)""")
    rep = lint_text(serial, LintContext())
    assert "NO-OVERLAP-WINDOW" in _rules(rep)
    overlapped = _module("""\
  p0 = f32[16] parameter(0)
  p1 = f32[16] parameter(1)
  cp = f32[16] collective-permute(p0), channel_id=1, source_target_pairs={{0,1},{1,0}}
  interior = f32[16] multiply(p1, p1)
  boundary = f32[16] add(cp, p0)
  ROOT r = f32[16] add(boundary, interior)""")
    assert "NO-OVERLAP-WINDOW" not in _rules(lint_text(overlapped,
                                                       LintContext()))
    # a pure-communication module (no compute at all) is not lintable for
    # overlap: nothing could ever hide the latency, rule stays silent
    comm_only = _module("""\
  p0 = f32[16] parameter(0)
  ar = f32[16] all-reduce(p0), channel_id=1, to_apply=add_f32
  ROOT r = f32[16] reshape(ar)""")
    assert "NO-OVERLAP-WINDOW" not in _rules(lint_text(comm_only,
                                                       LintContext()))


def test_ag_adjacency_counts_live_gathered_buffers():
    # ag1's result survives (via the non-compute reshape) to a consumer
    # BELOW ag2's definition, so both gathered buffers are live at once
    body = """\
  p0 = f32[16] parameter(0)
  p1 = f32[16] parameter(1)
  ag1 = f32[32] all-gather(p0), channel_id=1, dimensions={0}
  u1 = f32[32] multiply(ag1, ag1)
  ag2 = f32[32] all-gather(p1), channel_id=2, dimensions={0}
  u2 = f32[32] multiply(ag2, ag2)
  keep = f32[32] reshape(ag1)
  late = f32[32] add(keep, u2)
  ROOT r = f32[32] add(late, u1)"""
    over = lint_text(_module(body),
                     LintContext(extra={"fsdp_working_set": 1}))
    assert "AG-ADJACENCY" in _rules(over)
    assert any("2 gathered" in f.message for f in over.findings)
    within = lint_text(_module(body),
                       LintContext(extra={"fsdp_working_set": 2}))
    assert "AG-ADJACENCY" not in _rules(within)
    # rule is inactive unless the target opts in via the ctx key
    inactive = lint_text(_module(body), LintContext())
    assert "AG-ADJACENCY" not in _rules(inactive)
    # disjoint spans: ag1's buffer dies before ag2 is even defined
    streamed = """\
  p0 = f32[16] parameter(0)
  p1 = f32[16] parameter(1)
  ag1 = f32[32] all-gather(p0), channel_id=1, dimensions={0}
  u1 = f32[32] multiply(ag1, ag1)
  ag2 = f32[32] all-gather(p1), channel_id=2, dimensions={0}
  u2 = f32[32] multiply(ag2, ag2)
  ROOT r = f32[32] add(u1, u2)"""
    ok = lint_text(_module(streamed),
                   LintContext(extra={"fsdp_working_set": 1}))
    assert "AG-ADJACENCY" not in _rules(ok)


def test_donation_lost_reads_module_header():
    body = """\
  p0 = f32[16] parameter(0)
  ROOT r = f32[16] add(p0, p0)"""
    lost = lint_text(_module(body), LintContext(expect_donation=True))
    assert "DONATION-LOST" in _rules(lost)
    donated = lint_text(_module(body, "buffer_donor={ (0, {}) }"),
                        LintContext(expect_donation=True))
    assert "DONATION-LOST" not in _rules(donated)
    aliased = lint_text(
        _module(body, "input_output_alias={ {}: (0, {}, may-alias) }"),
        LintContext(expect_donation=True))
    assert "DONATION-LOST" not in _rules(aliased)


# ------------------------------------------------------------------ report
def test_report_shape_and_wire_annotation():
    txt = _module("""\
  p0 = f32[16] parameter(0)
  cp = f32[16] collective-permute(p0), channel_id=1, source_target_pairs={{0,1},{1,0}}
  ROOT r = f32[16] add(cp, p0)""")
    rep = lint_text(txt, LintContext(expected_permute_total=1),
                    target="fixture")
    assert rep.target == "fixture" and rep.n_collectives == 1
    assert rep.wire_bytes == pytest.approx(64.0)   # CP moves its payload once
    d = rep.to_dict()
    assert set(d) >= {"target", "ok", "n_collectives", "wire_bytes",
                      "findings"}
    assert json.dumps(d)                            # JSON-serializable
    assert rep.render().startswith("FAIL" if not rep.ok else "PASS")
    for f in rep.findings:
        fd = f.to_dict()
        assert {"rule", "severity", "message", "fix_hint"} <= set(fd)


# --------------------------------------------------- mutation fixtures (slow)
@pytest.mark.slow
def test_two_phase_mutations_trip_wire_and_overlap_rules():
    """two_phase is the sanctioned negative for both wire rules: the
    monolithic concatenated psum upcasts bf16 grads to the f32 accumulator
    dtype (WIRE-WIDEN), and the exchange->barrier->compute stencil leaves
    the collectives zero independent compute (NO-OVERLAP-WINDOW)."""
    code = """
    import json
    from repro.analysis.hlo_lint import lint_target
    wide = lint_target("broken_two_phase_grad_sync")
    barrier = lint_target("broken_two_phase_heat2d")
    clean = lint_target("heat2d_1d")
    print(json.dumps({
        "upcast_caught": "WIRE-WIDEN" in {f.rule for f in wide.errors},
        "barrier_caught":
            "NO-OVERLAP-WINDOW" in {f.rule for f in barrier.errors},
        "hdot_clean": clean.ok,
    }))
    """
    r = run_devices(code, 4)
    assert all(r.values()), r


@pytest.mark.slow
def test_cli_json_artifact_and_exit_codes(tmp_path):
    """`python -m repro.analysis.hlo_lint` is the CI entry point: exit 0 and
    a machine-readable JSON report for clean targets, exit 1 when a target
    carries an error finding."""
    import os
    import subprocess
    import sys

    from tests.test_system import REPO

    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)   # the CLI forces its own device count
    out_json = tmp_path / "lint.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis.hlo_lint",
         "-t", "halo1d,heat2d_1d", "--devices", "4",
         "--json", str(out_json)],
        capture_output=True, text=True, timeout=600, env=env)
    assert res.returncode == 0, res.stdout + res.stderr
    payload = json.loads(out_json.read_text())
    assert payload["ok"] is True
    assert [t["target"] for t in payload["targets"]] == ["halo1d",
                                                         "heat2d_1d"]
    assert all(t["n_collectives"] > 0 for t in payload["targets"])

    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis.hlo_lint",
         "-t", "broken_unpeeled_halo1d", "--devices", "4"],
        capture_output=True, text=True, timeout=600, env=env)
    assert res.returncode == 1, res.stdout + res.stderr
    assert "DEAD-DRAIN" in res.stdout
