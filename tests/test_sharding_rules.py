"""Logical-axis resolver: greedy candidates, divisibility fixups, no mesh-axis
reuse within a tensor — the mechanism that lets one rule set drive all 10
architectures (sharding/rules docstring)."""
from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (ShardingContext, resolve_pspec,
                                  use_sharding, with_logical)


@pytest.fixture(scope="module")
def ctx256():
    """Resolver-only context with a fake 16x16 mesh (no devices needed)."""

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16), object)

    return ShardingContext(FakeMesh())  # type: ignore[arg-type]


def test_divisible_dims_shard(ctx256):
    # llama3 wq: (d_model, heads, head_dim) = (16384, 128, 128)
    spec = resolve_pspec((16384, 128, 128), ("embed", "heads", "head_dim"),
                         ctx256)
    assert spec == P("data", "model")


def test_indivisible_heads_fall_back(ctx256):
    # llava: 56 heads % 16 != 0 -> replicate that dim, keep the others
    spec = resolve_pspec((7168, 56, 128), ("embed", "heads", "head_dim"),
                         ctx256)
    assert spec == P("data")


def test_vocab_fallback_granite(ctx256):
    # granite vocab 49155 is odd -> embedding replicates on vocab, shards d
    spec = resolve_pspec((49155, 2048), ("vocab", "embed"), ctx256)
    assert spec == P(None, "data")


def test_no_axis_reuse_within_tensor(ctx256):
    # both logical axes want 'model'; second must fall through
    spec = resolve_pspec((64, 64), ("seq", "vocab"), ctx256)
    flat = [a for e in spec if e for a in
            (e if isinstance(e, tuple) else (e,))]
    assert len(flat) == len(set(flat))
    assert spec[0] == "model"


@given(dim0=st.integers(1, 4096), dim1=st.integers(1, 4096))
@settings(max_examples=200, deadline=None)
def test_resolver_invariants(ctx256, dim0, dim1):
    """For any shape: placed axes divide their dims and are never reused."""
    spec = resolve_pspec((dim0, dim1), ("mlp", "heads"), ctx256)
    used = []
    for size, entry in zip((dim0, dim1), list(spec) + [None] * 2):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= ctx256.axis_size(a)
            used.append(a)
        assert size % prod == 0
    assert len(used) == len(set(used))


def test_with_logical_identity_outside_context():
    import jax.numpy as jnp

    x = jnp.ones((4, 4))
    assert with_logical(x, ("batch", "seq")) is x


def test_with_logical_applies_constraint(single_mesh):
    import jax
    import jax.numpy as jnp

    def f(x):
        return with_logical(x, ("batch", None)) * 2

    with use_sharding(single_mesh):
        y = jax.jit(f)(jnp.ones((4, 4)))
    np.testing.assert_allclose(np.asarray(y), 2.0)


def test_multi_pod_axes_collapse(ctx256):
    """('pod','data') candidates collapse to the axes present in the mesh."""
    spec = resolve_pspec((256, 64), ("batch", None), ctx256)
    assert spec == P("data")  # no 'pod' axis in a single-pod mesh
