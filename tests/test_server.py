"""BatchServer continuous batching: edge cases + the solo-serving oracle.

The load-bearing property: admission prefills at the exact prompt width
(batch 1 — no padding ever enters attention) and replaces the freed slot's
cache rows wholesale, so each request's greedy output is bit-identical to
serving it alone on a 1-slot server, for ANY interleaving of arrivals — and
therefore no slot can be reading another request's cache rows (any
cross-slot leak would perturb the logits and break bit-equality).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.registry import get_arch
from repro.models.model import ModelOptions, build_model, init_params
from repro.runtime.server import (
    BatchServer,
    Request,
    _mark_prefill_tail,
    _scatter_slot,
    make_slot_caches,
)

PROMPTS = [[5, 9, 3], [7, 1], [2, 2, 2, 2, 8], [11], [4, 6]]
MAX_NEW = [4, 6, 2, 1, 5]


@pytest.fixture(scope="module")
def model_and_params():
    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(),
                              num_layers=2)
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    return model, init_params(cfg, seed=0)


@pytest.fixture(scope="module")
def solo_outputs(model_and_params):
    """Each request served alone on a 1-slot continuous server — the oracle
    every interleaving must reproduce bit-identically."""
    model, params = model_and_params
    outs = []
    for p, m in zip(PROMPTS, MAX_NEW):
        srv = BatchServer(model, params, slots=1, max_len=16)
        srv.submit(Request(prompt=list(p), max_new_tokens=m))
        [r] = srv.run_continuous()
        outs.append(r.output)
    return outs


def _server(model_and_params, **kw):
    model, params = model_and_params
    kw.setdefault("max_len", 16)
    return BatchServer(model, params, **kw)


# ------------------------------------------------------------- oracle property
def test_continuous_matches_solo_for_any_interleaving(model_and_params,
                                                      solo_outputs):
    """Arrivals submitted up-front, reversed, and staggered mid-decode via
    the poll hook: per-request outputs must be bit-identical to the 1-slot
    solo server in every case."""
    reqs = list(zip(PROMPTS, MAX_NEW))

    def run(slots, order, stagger):
        srv = _server(model_and_params, slots=slots)
        pending = [Request(prompt=list(PROMPTS[j]), max_new_tokens=MAX_NEW[j],
                           rid=j) for j in order]
        if stagger is None:
            for r in pending:
                srv.submit(r)
            served = srv.run_continuous()
        else:
            it = {"n": -1}

            def poll():
                it["n"] += 1
                for r, at in zip(pending, stagger):
                    if at == it["n"]:
                        srv.submit(r)
                return any(at > it["n"] for at in stagger)

            served = srv.run_continuous(poll)
        assert len(served) == len(reqs)
        return {r.rid: r.output for r in served}

    for got in (run(2, range(len(reqs)), None),
                run(3, reversed(range(len(reqs))), None),
                run(2, range(len(reqs)), [0, 0, 2, 3, 5])):
        for j, exp in enumerate(solo_outputs):
            assert got[j] == exp


# ----------------------------------------------------------------- edge cases
def test_eos_on_first_decoded_token(model_and_params, solo_outputs):
    """eos == the first sampled token: the request completes at admission
    (zero decode steps) and the slot immediately admits the next request."""
    srv = _server(model_and_params, slots=1)
    for p, out in zip(PROMPTS[:3], solo_outputs[:3]):
        srv.submit(Request(prompt=list(p), max_new_tokens=8, eos_id=out[0]))
    served = srv.run_continuous()
    assert [r.output for r in served] == [[o[0]] for o in solo_outputs[:3]]
    assert srv.stats["decode_steps"] == 0
    assert srv.stats["admitted"] == 3


def test_all_slots_finish_same_step(model_and_params):
    srv = _server(model_and_params, slots=2)
    for _ in range(2):
        srv.submit(Request(prompt=[5, 9, 3], max_new_tokens=4))
    served = srv.run_continuous()
    assert len(served) == 2
    assert served[0].output == served[1].output      # identical requests
    # lockstep: one admission token + (max_new - 1) shared decode steps
    assert srv.stats["decode_steps"] == 3


def test_queue_longer_than_slots_across_refills(model_and_params):
    srv = _server(model_and_params, slots=2)
    want = []
    for i in range(7):
        m = 1 + (i % 3)
        want.append(m)
        srv.submit(Request(prompt=[3 + i], max_new_tokens=m))
    served = srv.run_continuous()
    assert len(served) == 7
    assert sorted(len(r.output) for r in served) == sorted(want)
    assert srv.stats["admitted"] == 7


def test_max_new_tokens_one(model_and_params):
    srv = _server(model_and_params, slots=2)
    srv.submit(Request(prompt=[5, 9, 3], max_new_tokens=1))
    [r] = srv.run_continuous()
    assert len(r.output) == 1
    assert srv.stats["decode_steps"] == 0            # never entered decode


def test_nongreedy_sampling_deterministic_under_fixed_seed(model_and_params):
    """Non-greedy keys derive from (request id, #generated), so a fixed seed
    pins the sampled streams regardless of slot count / interleaving."""

    def run(slots, seed):
        srv = _server(model_and_params, slots=slots, greedy=False, seed=seed)
        for p in PROMPTS[:3]:
            srv.submit(Request(prompt=list(p), max_new_tokens=5))
        return {r.rid: r.output for r in srv.run_continuous()}

    assert run(1, seed=7) == run(3, seed=7)
    assert run(3, seed=7) != run(3, seed=8)


def test_admission_jit_cached_per_prompt_length(model_and_params):
    srv = _server(model_and_params, slots=2)
    for p in ([1, 2], [3, 4], [5, 6], [7, 8, 9]):
        srv.submit(Request(prompt=list(p), max_new_tokens=2))
    srv.run_continuous()
    assert sorted(srv._admit_fns) == [2, 3]          # one program per plen


def test_wave_scheduler_still_serves(model_and_params):
    srv = _server(model_and_params, slots=2)
    for p, m in zip(PROMPTS, MAX_NEW):
        srv.submit(Request(prompt=list(p), max_new_tokens=m))
    served = srv.run_all()
    assert [len(r.output) for r in served] == MAX_NEW
    assert srv.stats["waves"] == 3                   # ceil(5 / 2)


# ------------------------------------------------------ cache-surgery isolation
def test_scatter_slot_touches_only_its_rows(model_and_params):
    """Admission surgery writes exactly the freed slot's rows: every other
    slot's k/v/pos rows are bit-identical before and after."""
    model, params = model_and_params
    slots, max_len, slot = 3, 16, 1
    before = make_slot_caches(model, slots, max_len)
    toks = jnp.asarray([[5, 9, 3]], jnp.int32)
    _, pc = jax.jit(lambda p, t: model.prefill(p, {"tokens": t},
                                               max_len=max_len))(params, toks)
    pc = _mark_prefill_tail(pc, 3)
    after = _scatter_slot(before, pc, jnp.asarray(slot, jnp.int32), slots)

    def rows(tree, i):
        # slot axis: -2 on per-slot pos leaves (L, slots, w), the axis sized
        # `slots` on k/v leaves (L, slots, w, hkv, hd)
        return jax.tree.map(
            lambda a: np.asarray(a[:, i] if a.shape[1] == slots else a[i]),
            tree)

    for other in (0, 2):
        a, b = rows(before, other), rows(after, other)
        assert all(np.array_equal(x, y) for x, y in
                   zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    got = rows(after, slot)
    exp = jax.tree.map(lambda a: np.asarray(a), pc)
    assert all(np.array_equal(np.asarray(x), np.asarray(y).squeeze())
               or np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(got), jax.tree.leaves(exp)))


def test_slot_caches_pos_initialized_empty(model_and_params):
    """init_caches zero-fills the pos ring (position 0 = attended!); the
    continuous layout must start every slot's ring at -1 (empty)."""
    model, _ = model_and_params
    caches = make_slot_caches(model, 4, 16)
    pos_leaves = [leaf for path, leaf in
                  jax.tree_util.tree_flatten_with_path(caches)[0]
                  if getattr(path[-1], "key", None) == "pos"]
    assert pos_leaves
    for leaf in pos_leaves:
        assert leaf.shape[-2] == 4                   # per-slot axis
        assert bool((leaf == -1).all())
