"""Measured-cost dynamic re-partitioning: the weighted N-D partition, the
cost model, the unified solver mesh contract, and the re-cut drivers.

Invariants under test:
  * ``weights=None`` is bit-identical to the historical uniform split (the
    oracle tests elsewhere stay valid unchanged);
  * a weighted cut still covers the extent with contiguous, monotone,
    non-empty parts, and balances summed cost within ``max(weights)`` of the
    total/parts ideal;
  * the canonical cut (``part_extents``) is hashable and idempotent — the
    jitted-solver caches key on it, so an unchanged cut never recompiles;
  * a re-cut never changes the numerics, only the schedule.
"""
from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cost import CostModel
from repro.core.domain import (decompose_grid, interior_boxes, interior_cuts,
                               part_extents, split_ranges, _split_extent)
from repro.runtime.ft import reassign_host_shards

extents = st.integers(min_value=1, max_value=64)
parts_st = st.integers(min_value=1, max_value=8)


# ---------------------------------------------------- weighted split (domain)
@given(extent=extents, parts=parts_st)
@settings(max_examples=200, deadline=None)
def test_weights_none_is_uniform(extent, parts):
    assert split_ranges(extent, parts, None) == _split_extent(extent, parts)


@given(extent=extents, parts=parts_st, data=st.data())
@settings(max_examples=200, deadline=None)
def test_weighted_cover_contiguous_monotone(extent, parts, data):
    w = data.draw(st.lists(st.floats(0.0, 10.0), min_size=extent,
                           max_size=extent))
    ranges = split_ranges(extent, parts, w)
    assert len(ranges) == parts
    assert ranges[0][0] == 0 and ranges[-1][1] == extent
    for (a0, b0), (a1, b1) in zip(ranges, ranges[1:]):
        assert b0 == a1          # contiguous, monotone cuts
    if extent >= parts:
        assert all(b > a for a, b in ranges)  # every part keeps >= 1 cell


@given(extent=st.integers(8, 64), parts=st.integers(1, 4), data=st.data())
@settings(max_examples=200, deadline=None)
def test_weighted_balance_bound(extent, parts, data):
    w = data.draw(st.lists(st.floats(0.0, 10.0), min_size=extent,
                           max_size=extent))
    ranges = split_ranges(extent, parts, w)
    total = sum(w)
    worst = max(sum(w[a:b]) for a, b in ranges)
    assert worst <= total / parts + (max(w) if w else 0.0) + 1e-9


def test_flat_weights_collapse_to_uniform():
    """Equal per-cell costs carry no cut preference: the weighted path must
    land exactly on the uniform split, or flat re-measurements would flip
    the cut and recompile for nothing."""
    for extent, parts in ((14, 4), (30, 4), (7, 3), (16, 5)):
        for c in (1.0, 2.5):
            assert (split_ranges(extent, parts, [c] * extent)
                    == _split_extent(extent, parts))
    assert split_ranges(10, 3, [0.0] * 10) == _split_extent(10, 3)


def test_explicit_extents_and_idempotence():
    assert split_ranges(10, 3, (4, 3, 3)) == [(0, 4), (4, 7), (7, 10)]
    for w in (None, (4, 3, 3), [5.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0,
                                1.0, 1.0]):
        cut = part_extents(10, 3, w)
        assert sum(cut) == 10 and len(cut) == 3
        assert part_extents(10, 3, cut) == cut  # canonical form is a fixpoint


def test_weighted_split_validation():
    with pytest.raises(ValueError):
        split_ranges(10, 3, [1.0] * 7)          # wrong length
    with pytest.raises(ValueError):
        split_ranges(10, 3, [-1.0] + [1.0] * 9)  # negative cost
    with pytest.raises(ValueError):
        split_ranges(10, 2, (11, -1))           # negative explicit extent
    with pytest.raises(ValueError):
        split_ranges(10, 0)


def test_skewed_weights_shift_the_cut():
    """Mass on the left yields smaller left parts (equal-cost parts)."""
    w = [4.0] * 8 + [1.0] * 24
    cut = part_extents(32, 4, w)
    assert cut[0] < cut[-1]
    assert sum(cut) == 32


# ------------------------------------------------ weighted interior chunking
def test_interior_boxes_weighted_cover_and_none_identity():
    shape, width, grid = (20, 18), 1, (3, 2)
    uniform = interior_boxes(shape, width, grid)
    assert interior_boxes(shape, width, grid, weights=None) == uniform
    w = ([5.0] * 6 + [1.0] * 12, None)
    boxes = interior_boxes(shape, width, grid, weights=w)
    cover = np.zeros(shape, np.int32)
    for b in boxes:
        cover[b.slices()] += 1
    interior = cover[width:-width, width:-width]
    assert (interior == 1).all()
    assert cover.sum() == interior.size  # nothing leaks into the halo frame


def test_interior_cuts_matches_boxes():
    shape, width, grid = (20, 18), 1, (3, 2)
    w = ([5.0] * 6 + [1.0] * 12, None)
    cuts = interior_cuts(shape, width, grid, weights=w)
    boxes = interior_boxes(shape, width, grid, weights=w)
    dim0 = sorted({(b.start[0], b.stop[0]) for b in boxes})
    assert tuple(b - a for a, b in dim0) == cuts[0]
    assert sum(cuts[0]) == shape[0] - 2 * width
    assert sum(cuts[1]) == shape[1] - 2 * width


# ----------------------------------------------------------------- CostModel
def test_cost_model_ema_and_normalization():
    cm = CostModel(alpha=0.5)
    assert cm.record("k", 10.0, cells=10) == pytest.approx(1.0)
    assert cm.record("k", 30.0, cells=10) == pytest.approx(2.0)  # 0.5/0.5 mix
    assert cm.ema("k") == pytest.approx(2.0)
    assert cm.observations("k") == 2 and len(cm) == 1
    assert cm.ema("missing", default=7.0) == 7.0
    with pytest.raises(ValueError):
        cm.record("k", -1.0)
    with pytest.raises(ValueError):
        CostModel(alpha=0.0)


def test_cost_model_weights_along_marginalizes():
    """Two chunks along dim 0 (rates 3 and 1) -> the dim-0 per-cell profile
    is hot then cold, and the next cut shrinks the hot chunk; unmeasured
    chunks fall back to the mean-rate prior."""
    cm = CostModel(alpha=1.0)
    ranges = [[(0, 8), (8, 16)], [(0, 10)]]
    cm.record((0, 0), 3.0 * 8 * 10, cells=80)
    cm.record((1, 0), 1.0 * 8 * 10, cells=80)
    prof = cm.weights_along(ranges)
    assert prof[0][:8] == (3.0,) * 8 and prof[0][8:] == (1.0,) * 8
    assert prof[1] == (2.0,) * 10  # dim-1 averages over both dim-0 chunks
    cut = part_extents(16, 2, prof[0])
    assert cut[0] < cut[1]

    empty = CostModel()
    assert empty.mean_rate() == 1.0
    prof0 = empty.weights_along(ranges)
    assert prof0[0] == (1.0,) * 16  # prior only -> flat -> uniform cut
    assert part_extents(16, 2, prof0[0]) == part_extents(16, 2, None)


# ----------------------------------------------- unified solver mesh contract
def test_normalize_mesh_axes_contract(monkeypatch):
    import repro.core.stencil as stencil

    norm = stencil.normalize_mesh_axes
    assert norm(("data",), "heat2d_solve", (1, 2)) == ("data",)
    assert norm(["rows", "cols"], "heat2d_solve", (1, 2)) == ("rows", "cols")

    monkeypatch.setattr(stencil, "_STR_AXES_WARNED", set())
    with pytest.warns(DeprecationWarning, match="heat2d_solve"):
        assert norm("data", "heat2d_solve", (1, 2)) == ("data",)

    with pytest.raises(ValueError, match="hpccg_solve.*1 or 2 or 3"):
        norm(("a", "b", "c", "d"), "hpccg_solve", (1, 2, 3))
    with pytest.raises(ValueError, match="rk3_solve"):
        norm((), "rk3_solve", (1, 2))
    with pytest.raises(ValueError, match="repeats"):
        norm(("data", "data"), "heat2d_solve", (1, 2))
    with pytest.raises(ValueError, match="axis names"):
        norm(("data", 1), "heat2d_solve", (1, 2))
    with pytest.raises(ValueError):
        norm(42, "heat2d_solve", (1, 2))


def test_deprecated_halo_aliases_warn(monkeypatch):
    import jax.numpy as jnp

    import repro.core.halo as halo

    monkeypatch.setattr(halo, "_DEPRECATION_WARNED", set())
    u = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)
    lo, hi = jnp.zeros((1, 4)), jnp.zeros((1, 4))
    with pytest.warns(DeprecationWarning, match="stencil_with_halo_nd"):
        old = halo.stencil_with_halo(u, lo, hi, lambda p: p[1:-1], 1, 0, 2)
    new = halo.stencil_with_halo_nd(u, [(lo, hi)], lambda p: p[1:-1], 1,
                                    (0,), (2,))
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


# --------------------------------------------- solver re-cut (single device)
def test_heat2d_chunk_weights_numerics_and_cache(single_mesh):
    from repro.core.stencil import _heat2d_solver, heat2d_init, heat2d_solve

    u0 = heat2d_init(32, 32)
    ref, res_ref = heat2d_solve(u0, single_mesh, ("data",), 6, "hdot", 4)
    n0 = _heat2d_solver.cache_info().currsize

    # uniform per-cell costs collapse onto the unweighted program
    u1, _ = heat2d_solve(u0, single_mesh, ("data",), 6, "hdot", 4,
                         chunk_weights=([1.0] * 30,))
    assert _heat2d_solver.cache_info().currsize == n0
    np.testing.assert_array_equal(np.asarray(u1), np.asarray(ref))

    # a skewed cut recompiles exactly once, then caches
    skew = ([9.0] * 8 + [1.0] * 22,)
    u2, _ = heat2d_solve(u0, single_mesh, ("data",), 6, "hdot", 4,
                         chunk_weights=skew)
    n1 = _heat2d_solver.cache_info().currsize
    assert n1 == n0 + 1
    u3, _ = heat2d_solve(u0, single_mesh, ("data",), 6, "hdot", 4,
                         chunk_weights=skew)
    assert _heat2d_solver.cache_info().currsize == n1
    np.testing.assert_allclose(np.asarray(u2), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(u2), np.asarray(u3))

    with pytest.raises(ValueError, match="chunk_weights"):
        heat2d_solve(u0, single_mesh, ("data",), 2, "hdot", 4,
                     chunk_weights=([1.0] * 30, None))


def test_heat2d_solve_rebalanced_recuts(single_mesh):
    from repro.core.stencil import heat2d_init, heat2d_solve
    from repro.runtime.rebalance import heat2d_solve_rebalanced

    u0 = heat2d_init(32, 32)
    ref, res_ref = heat2d_solve(u0, single_mesh, ("data",), 12, "hdot", 4)

    def cost_fn(idx, shape):
        cells = int(np.prod(shape))
        return (4.0 if idx[0] == 0 else 1.0) * cells * 1e-6

    u, res, info = heat2d_solve_rebalanced(
        u0, single_mesh, ("data",), 12, "hdot", 4, rebalance_every=4,
        chunk_cost_fn=cost_fn)
    np.testing.assert_allclose(np.asarray(u), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(res), np.asarray(res_ref),
                               rtol=1e-6, atol=1e-6)
    assert info["recompiles"] >= 1
    first, last = info["cut_history"][0][0], info["cut_history"][-1][0]
    assert last[0] < first[0]  # the slow chunk shrank

    # no per-chunk signal -> the cut must stay put
    u2, _, info2 = heat2d_solve_rebalanced(
        u0, single_mesh, ("data",), 12, "hdot", 4, rebalance_every=4)
    assert info2["recompiles"] == 0
    np.testing.assert_array_equal(np.asarray(u2), np.asarray(ref))

    with pytest.raises(ValueError, match="rebalance_every"):
        heat2d_solve_rebalanced(u0, single_mesh, ("data",), 4,
                                rebalance_every=-1)


# -------------------------------------------------- reassignment edge cases
def test_reassign_host_shards_duplicates_dedupe():
    assert reassign_host_shards(4, [1, 1, 1]) == reassign_host_shards(4, [1])


def test_reassign_host_shards_range_edges():
    with pytest.raises(ValueError):
        reassign_host_shards(0, [])
    with pytest.raises(ValueError):
        reassign_host_shards(4, [-1])
    with pytest.raises(ValueError):
        reassign_host_shards(4, [4])
    with pytest.raises(RuntimeError):
        reassign_host_shards(3, [0, 1, 2])
    assert reassign_host_shards(1, []) == {0: [0]}
    # every lost slice lands on exactly one survivor, none dropped
    out = reassign_host_shards(5, [0, 2])
    served = sorted(s for v in out.values() for s in v)
    assert served == [0, 1, 2, 3, 4]
    assert set(out) == {1, 3, 4}
