"""Sharded flash-decode vs the single-device dense oracle (subprocess with a
real multi-device mesh — the §Perf cell-C optimization's correctness proof)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_devices(code: str, devices: int, timeout: int = 600) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
@pytest.mark.parametrize("window", [None, 32])
def test_flash_decode_matches_dense_oracle(window):
    code = f"""
    import json, dataclasses, jax, jax.numpy as jnp, numpy as np
    from repro.config.registry import get_arch
    from repro.models import attention as attn
    from repro.models.layers import init_from_specs
    from repro.launch.mesh import make_mesh
    from repro.sharding.rules import use_sharding

    window = {window!r}
    cfg = dataclasses.replace(get_arch("qwen3-8b").reduced(), num_layers=1)
    p = init_from_specs(attn.attention_specs(cfg, jnp.float32),
                        jax.random.PRNGKey(0))
    mesh = make_mesh((2, 4), ("data", "model"))
    b, w = 4, 64
    x_seq = jax.random.normal(jax.random.PRNGKey(1), (b, 48, cfg.d_model),
                              jnp.float32) * 0.1

    def run(sharded):
        cache = attn.make_cache(cfg, b, w, jnp.float32)
        outs = []
        for t in range(x_seq.shape[1]):
            def step(x1, cache, t=t):
                if sharded:
                    with use_sharding(mesh):
                        return attn.decode_attention(
                            p, x1, cfg, cache, jnp.asarray(t, jnp.int32),
                            window=window)
                return attn.decode_attention(
                    p, x1, cfg, cache, jnp.asarray(t, jnp.int32),
                    window=window)
            y, cache = jax.jit(step)(x_seq[:, t:t+1], cache)
            outs.append(np.asarray(y))
        return np.concatenate(outs, axis=1)

    dense = run(sharded=False)
    flash = run(sharded=True)
    err = float(np.max(np.abs(dense - flash)))
    print(json.dumps({{"max_err": err}}))
    """
    r = run_devices(code, 8)
    assert r["max_err"] < 2e-4, r
