"""2-D (rows x cols) decomposition machinery, single-device (the 2x2 / 4x1
real-mesh equivalences live in test_system.py). The safety property is the
same as 1-D: every schedule/knob/topology must be numerically identical to
the two-phase oracle — including the corner cells, which a corner-free
exchange must still get right for star stencils."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.domain import interior_boxes
from repro.core.halo import (halo_scan_2d, pad_with_halo_2d, stencil_apply_2d,
                             stencil_with_halo_2d)


@pytest.fixture(scope="module")
def grid_mesh():
    from repro.launch.mesh import make_grid_mesh

    return make_grid_mesh(1, 1)


def _star_fn(width: int):
    """Separable star stencil of `width` (reads the full cross, no corners).
    Input padded by `width` on both dims; returns the un-padded update."""
    def fn(p):
        n0, n1 = p.shape[0] - 2 * width, p.shape[1] - 2 * width
        acc = 0.0
        for d in range(-width, width + 1):
            acc = (acc + p[width + d:width + d + n0, width:width + n1]
                   + p[width:width + n0, width + d:width + d + n1])
        return acc / (2 * (2 * width + 1))
    return fn


def _shmap(fn, mesh):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=(P("rows", "cols"),),
                                 out_specs=P("rows", "cols")))


def test_interior_boxes_partition():
    """The task-level chunk grid tiles exactly the interior of the block."""
    boxes = interior_boxes((17, 13), 2, (3, 2))
    assert len(boxes) == 6
    cells = set()
    for b in boxes:
        for i in range(b.start[0], b.stop[0]):
            for j in range(b.start[1], b.stop[1]):
                assert (i, j) not in cells
                cells.add((i, j))
    assert cells == {(i, j) for i in range(2, 15) for j in range(2, 11)}


@pytest.mark.parametrize("subdomains", [(1, 1), (2, 2), (3, 2), 4, (16, 16)])
@pytest.mark.parametrize("periodic", [False, True])
def test_stencil_hdot_2d_matches_two_phase(grid_mesh, subdomains, periodic):
    """The 2-D chunk-grid knob must not change numerics for any grainsize."""
    u = jax.random.normal(jax.random.PRNGKey(0), (24, 20), jnp.float32)
    fn = _star_fn(1)
    want = _shmap(lambda x: stencil_apply_2d(
        x, fn, ("rows", "cols"), 1, (0, 1), periodic, "two_phase"), grid_mesh)(u)
    got = _shmap(lambda x: stencil_apply_2d(
        x, fn, ("rows", "cols"), 1, (0, 1), periodic, "hdot", subdomains),
        grid_mesh)(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("mode", ["hdot", "two_phase"])
@pytest.mark.parametrize("width,shape", [(1, (17, 13)), (1, (16, 20)),
                                         (2, (21, 18))])
def test_halo_scan_2d_equals_iterated_apply(grid_mesh, mode, width, shape):
    """halo_scan_2d(steps=k) == k iterated 2-D applies, odd AND even interior
    sizes, both schedules."""
    steps = 4
    u = jax.random.normal(jax.random.PRNGKey(1), shape, jnp.float32)
    fn = _star_fn(width)

    got, _ = jax.jit(jax.shard_map(
        lambda x: halo_scan_2d(x, fn, ("rows", "cols"), width, (0, 1), steps,
                               periodic=True, mode=mode, subdomains=(3, 2)),
        mesh=grid_mesh, in_specs=(P("rows", "cols"),),
        out_specs=(P("rows", "cols"), P())))(u)

    def iterate(x):
        for _ in range(steps):
            x = stencil_apply_2d(x, fn, ("rows", "cols"), width, (0, 1),
                                 True, "two_phase")
        return x

    want = _shmap(iterate, grid_mesh)(u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_stencil_with_halo_2d_uses_given_halos(grid_mesh):
    """Pre-exchanged face halos (random, not wrap-around) flow into the right
    cells — including the strip corners, via the corner-free assembly."""
    k = jax.random.PRNGKey(2)
    u = jax.random.normal(k, (18, 14), jnp.float32)
    halos = (jax.random.normal(jax.random.fold_in(k, 1), (1, 14), jnp.float32),
             jax.random.normal(jax.random.fold_in(k, 2), (1, 14), jnp.float32),
             jax.random.normal(jax.random.fold_in(k, 3), (18, 1), jnp.float32),
             jax.random.normal(jax.random.fold_in(k, 4), (18, 1), jnp.float32))
    fn = _star_fn(1)
    got = jax.jit(functools.partial(stencil_with_halo_2d, stencil_fn=fn,
                                    width=1, dims=(0, 1),
                                    subdomains=(2, 3)))(u, halos)
    want = fn(pad_with_halo_2d(u, halos, 1, (0, 1)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_heat2d_2d_mesh_matches_slab_and_numpy(grid_mesh):
    """heat2d_solve on a (rows, cols) topology == the 1-D slab solver == the
    classic numpy 5-point sweep, both schedules."""
    from repro.core.stencil import heat2d_init, heat2d_solve
    from repro.launch.mesh import make_mesh

    u0 = heat2d_init(32, 32)
    mesh1 = make_mesh((1,), ("data",))
    want, res_want = heat2d_solve(u0, mesh1, "data", 12, mode="two_phase")
    for mode in ("two_phase", "hdot"):
        got, res = heat2d_solve(u0, grid_mesh, ("rows", "cols"), 12, mode=mode)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_allclose(np.asarray(res), np.asarray(res_want),
                                   rtol=1e-5)
    up = np.pad(np.asarray(u0), 1)
    one = 0.25 * (up[:-2, 1:-1] + up[2:, 1:-1] + up[1:-1, :-2] + up[1:-1, 2:])
    got1, _ = heat2d_solve(u0, grid_mesh, ("rows", "cols"), 1, mode="hdot")
    np.testing.assert_allclose(np.asarray(got1), one, rtol=1e-6, atol=1e-7)


def test_hpccg_2d_mesh_matches_slab(grid_mesh):
    """CG on the (y, z) 2-D topology converges identically to the z-slab
    solver — exercises the corner-carrying two-hop exchange."""
    from repro.core.stencil import hpccg_solve
    from repro.launch.mesh import make_mesh

    b = jax.random.normal(jax.random.PRNGKey(3), (10, 12, 12), jnp.float32)
    mesh1 = make_mesh((1,), ("data",))
    _, h_want = hpccg_solve(b, mesh1, "data", 15, mode="two_phase")
    for mode in ("two_phase", "hdot"):
        x, h = hpccg_solve(b, grid_mesh, ("rows", "cols"), 15, mode=mode)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_want),
                                   rtol=1e-4)


def test_heat2d_kernel_sharded_matches_plain(grid_mesh):
    """The Pallas tile kernel with the exchanged halo ring, run per-shard on
    a 1x1 grid mesh, equals the plain kernel (both impls)."""
    from repro.kernels.heat2d import ops as heat_ops

    u = jax.random.normal(jax.random.PRNGKey(4), (64, 64), jnp.float32)
    want = heat_ops.heat2d_sweep(u, tile=(32, 32), sweeps=2, impl="ref")
    got = heat_ops.heat2d_sweep_sharded(u, grid_mesh, ("rows", "cols"),
                                        tile=(32, 32), sweeps=2, impl="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    got_p = heat_ops.heat2d_sweep_sharded(u, grid_mesh, ("rows", "cols"),
                                          tile=(32, 32), sweeps=2,
                                          impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_heat2d_kernel_halo_ring_pallas_vs_ref():
    """Random (non-zero) halo ring: pallas strips == ref oracle."""
    from repro.kernels.heat2d import ops as heat_ops

    k = jax.random.PRNGKey(5)
    u = jax.random.normal(k, (64, 96), jnp.float32)
    halo = (jax.random.normal(jax.random.fold_in(k, 1), (1, 96), jnp.float32),
            jax.random.normal(jax.random.fold_in(k, 2), (1, 96), jnp.float32),
            jax.random.normal(jax.random.fold_in(k, 3), (64, 1), jnp.float32),
            jax.random.normal(jax.random.fold_in(k, 4), (64, 1), jnp.float32))
    got = heat_ops.heat2d_sweep(u, tile=(32, 32), sweeps=3, impl="pallas",
                                interpret=True, halo=halo)
    want = heat_ops.heat2d_sweep(u, tile=(32, 32), sweeps=3, impl="ref",
                                 halo=halo)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
