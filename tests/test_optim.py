"""Optimizer + compression: AdamW against a NumPy reference, moment dtypes,
chunked update equivalence, int8 error-feedback properties."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         bf16_compress, bf16_decompress, ef_compress_update,
                         fp8_compress, fp8_decompress, int8_compress,
                         int8_decompress, warmup_cosine, wire_codec)


def _numpy_adamw(g, m, v, p, lr, cfg, step):
    g = 1.0 * g  # no clip when gnorm small (clip factor == 1 in this regime)
    b1, b2 = cfg.beta1, cfg.beta2
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mh = m / (1 - b1 ** step)
    vh = v / (1 - b2 ** step)
    return p - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * p), m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1e9)  # disable clip for the oracle
    params = {"w": jnp.asarray([[0.5, -0.25], [1.0, 2.0]], jnp.float32)}
    state = adamw_init(params)
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.05]], jnp.float32)}
    p_np = np.asarray(params["w"]).copy()
    m_np = np.zeros_like(p_np)
    v_np = np.zeros_like(p_np)
    for step in range(1, 4):
        params, state, _ = adamw_update(g, state, params, cfg,
                                        jnp.asarray(1e-2))
        p_np, m_np, v_np = _numpy_adamw(np.asarray(g["w"]), m_np, v_np, p_np,
                                        1e-2, cfg, step)
        np.testing.assert_allclose(np.asarray(params["w"]), p_np,
                                   rtol=1e-5, atol=1e-6)


def test_adamw_grad_clip_caps_update():
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, gnorm = adamw_update(g, state, params, cfg, jnp.asarray(1.0))
    assert float(gnorm) == pytest.approx(200.0)  # reported pre-clip


def test_adamw_moment_dtype_preserved():
    params = {"w": jnp.zeros((8,), jnp.bfloat16)}
    state = adamw_init(params, moment_dtype=jnp.bfloat16)
    g = {"w": jnp.ones((8,), jnp.bfloat16)}
    params, state, _ = adamw_update(g, state, params, AdamWConfig(),
                                    jnp.asarray(1e-3))
    assert state["m"]["w"].dtype == jnp.bfloat16
    assert state["v"]["w"].dtype == jnp.bfloat16
    assert params["w"].dtype == jnp.bfloat16


def test_adamw_chunked_equals_plain():
    """chunk_leading (per-layer lax.map update) must be a pure perf knob."""
    L = 6
    params = {"stack": jnp.arange(L * 8, dtype=jnp.float32).reshape(L, 8) / 10,
              "flat": jnp.ones((5,), jnp.float32)}
    g = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    cfg = AdamWConfig()
    s1 = adamw_init(params)
    s2 = adamw_init(params)
    p1, s1, _ = adamw_update(g, s1, params, cfg, jnp.asarray(1e-3),
                             chunk_leading=0)
    p2, s2, _ = adamw_update(g, s2, params, cfg, jnp.asarray(1e-3),
                             chunk_leading=L)
    for k in params:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   rtol=1e-6, atol=1e-7)


def test_warmup_cosine_shape():
    lr0 = float(warmup_cosine(0, 1e-3, 100, 1000))
    lr_w = float(warmup_cosine(100, 1e-3, 100, 1000))
    lr_end = float(warmup_cosine(1000, 1e-3, 100, 1000))
    assert lr0 == 0.0
    assert lr_w == pytest.approx(1e-3, rel=1e-3)
    assert lr_end == pytest.approx(1e-4, rel=1e-2)  # final_frac=0.1


# ------------------------------------------------------------- compression
@given(scale=st.floats(0.01, 100.0))
@settings(max_examples=30, deadline=None)
def test_int8_roundtrip_bounded_error(scale):
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * scale
    payload = int8_compress(x)
    y = int8_decompress(payload)
    max_err = float(jnp.max(jnp.abs(x - y)))
    # quantization step = max|x| / 127
    assert max_err <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


@pytest.mark.parametrize("scale", [1e-3, 0.1, 1.0, 37.0, 1e3])
def test_bf16_roundtrip_relative_error(scale):
    """bf16 shares f32's exponent, so round-trip error is purely the 8-bit
    significand: elementwise relative error <= 2^-8 at any magnitude."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * scale
    y = bf16_decompress(bf16_compress(x))
    assert y.dtype == jnp.float32
    err = np.abs(np.asarray(x) - np.asarray(y))
    bound = np.abs(np.asarray(x)) * 2.0**-8 + 1e-38
    np.testing.assert_array_less(err, bound)


@pytest.mark.parametrize("scale", [1e-3, 0.1, 1.0, 37.0, 1e3])
def test_fp8_roundtrip_bounded_error(scale):
    """fp8 e4m3 with a per-tensor scale: normal values round to 3 mantissa
    bits (rel err <= 2^-3), the subnormal tail to an absolute step of the
    scaled quantum — both bounds independent of the tensor's magnitude."""
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * scale
    payload = fp8_compress(x)
    y = fp8_decompress(payload)
    err = np.abs(np.asarray(x) - np.asarray(y))
    s = float(payload["scale"])
    bound = np.maximum(np.abs(np.asarray(x)) * 2.0**-3, s * 2.0**-9) + 1e-38
    assert (err <= bound).all()


def test_fp8_scale_saturates_at_amax():
    # the largest-magnitude element maps exactly onto the e4m3 max (448):
    # nothing clips, and decompress restores it to full precision
    x = jnp.array([-7.0, 0.5, 3.5])
    y = fp8_decompress(fp8_compress(x))
    np.testing.assert_allclose(float(y[0]), -7.0, rtol=1e-6)


def test_wire_codec_registry():
    for kind in ("bf16", "fp8", "int8"):
        compress, decompress = wire_codec(kind)
        x = jax.random.normal(jax.random.PRNGKey(2), (32,))
        y = decompress(compress(x))
        assert y.shape == x.shape and y.dtype == jnp.float32
    with pytest.raises(ValueError, match="unknown wire codec"):
        wire_codec("fp4")


def test_error_feedback_with_fp8_codec_converges():
    """EF composes with any wire codec: the fp8 residual is carried, so the
    mean of sent updates converges to the true gradient."""
    g = jax.random.normal(jax.random.PRNGKey(3), (64,)) * 0.1
    err = jnp.zeros_like(g)
    sent = []
    for _ in range(50):
        payload, err = ef_compress_update(
            g, err, compress=fp8_compress, decompress=fp8_decompress)
        sent.append(fp8_decompress(payload))
    avg = np.mean(np.stack([np.asarray(s) for s in sent]), axis=0)
    np.testing.assert_allclose(avg, np.asarray(g), rtol=0.08, atol=0.02)


def test_error_feedback_accumulates_residual():
    """EF: the compression residual is carried, so the MEAN of quantized
    updates converges to the true gradient (unbiased in the long run)."""
    g = jax.random.normal(jax.random.PRNGKey(1), (64,)) * 0.1
    err = jnp.zeros_like(g)
    sent = []
    for _ in range(50):
        payload, err = ef_compress_update(g, err)
        sent.append(int8_decompress(payload))
    avg = np.mean(np.stack([np.asarray(s) for s in sent]), axis=0)
    np.testing.assert_allclose(avg, np.asarray(g), rtol=0.08, atol=0.02)
