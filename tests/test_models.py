"""Per-arch smoke tests (REQUIRED by the brief): every assigned architecture
instantiates a REDUCED same-family config and runs one forward/train step on
CPU, asserting output shapes and no NaNs. Plus prefill/decode consistency."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config.registry import get_arch, list_archs
from repro.models.model import ModelOptions, build_model

ARCHS = list_archs()


def _batch(cfg, b=2, s=64):
    batch = {"tokens": jnp.maximum(jnp.arange(b * s, dtype=jnp.int32)
                                   .reshape(b, s) % cfg.vocab_size, 1),
             "targets": jnp.zeros((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jnp.ones((b, cfg.num_vision_patches, cfg.d_model),
                                    jnp.bfloat16) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((b, cfg.encdec.enc_seq, cfg.d_model),
                                   jnp.bfloat16) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one loss+grad step; finite loss, finite grads."""
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.train_loss))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(l, np.float32)).all()
                          for l in leaves)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_shapes(arch):
    cfg = get_arch(arch).reduced()
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    b, cache_len = 2, 128
    caches = model.init_caches(b, cache_len)
    token = jnp.ones((b, 1), jnp.int32)
    logits, new_caches = jax.jit(model.decode_step)(
        params, token, caches, jnp.asarray(5, jnp.int32))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    assert jax.tree.structure(new_caches) == jax.tree.structure(caches)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mixtral-8x7b", "mamba2-780m",
                                  "recurrentgemma-2b"])
def test_prefill_decode_matches_full_forward(arch):
    """Teacher-forcing consistency: prefill(t[:n]) then decode(t[n]) must give
    the same final logits as prefill(t[:n+1]) — the cache IS the state."""
    import dataclasses

    cfg = get_arch(arch).reduced()
    if cfg.moe is not None:
        # capacity-based token dropping differs between S=n and S=n+1
        # prefills (different capacity ceil) — that is an orthogonal MoE
        # semantic; the cache hand-off is validated drop-free.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    b, n = 1, 33
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, n + 1), 1,
                              cfg.vocab_size)
    logits_full, _ = model.prefill(params, {"tokens": toks})
    # prefill the prefix into a cache with headroom for the decode step
    _, caches = model.prefill(params, {"tokens": toks[:, :n]}, max_len=n + 1)
    logits_inc, _ = model.decode_step(params, toks[:, n:n + 1], caches,
                                      jnp.asarray(n, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_full[:, -1], np.float32),
        np.asarray(logits_inc[:, -1], np.float32), rtol=3e-2, atol=6e-2)


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-780m"])
def test_scan_equals_unrolled(arch):
    """Scanned and unrolled stacks are the same function."""
    cfg = get_arch(arch).reduced()
    m_scan = build_model(cfg, ModelOptions(attn_impl="dense", scan_layers=True))
    m_unrl = build_model(cfg, ModelOptions(attn_impl="dense", scan_layers=False))
    p_scan = m_scan.init(jax.random.PRNGKey(0))
    # re-layout scanned params (stacked leaves) into the unrolled list form
    stacked = p_scan["layers"]
    unrolled = [jax.tree.map(lambda x, i=i: x[i], stacked)
                for i in range(cfg.num_layers)]
    p_unrl = dict(p_scan)
    p_unrl["layers"] = unrolled
    batch = _batch(cfg)
    l1 = m_scan.train_loss(p_scan, batch)
    l2 = m_unrl.train_loss(p_unrl, batch)
    # scan and unrolled fuse differently -> bf16 reassociation noise only
    np.testing.assert_allclose(float(l1), float(l2), rtol=5e-3)


def test_moe_param_count_matches_hf():
    """Full configs reproduce published parameter counts (sanity on the exact
    assigned configs, not the reduced ones)."""
    assert abs(get_arch("mixtral-8x7b").num_params() / 46.7e9 - 1) < 0.01
    assert abs(get_arch("qwen3-moe-30b-a3b").num_params() / 30.5e9 - 1) < 0.01
    assert abs(get_arch("llama3-405b").num_params() / 405.8e9 - 1) < 0.01
    assert abs(get_arch("qwen3-8b").num_params() / 8.19e9 - 1) < 0.01


def test_vlm_patch_prefix_excluded_from_loss():
    cfg = get_arch("llava-next-34b").reduced()
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss = model.train_loss(params, batch)
    assert np.isfinite(float(loss))
