"""analysis/memtraffic: ring-model collective wire bytes (the linter's
per-finding annotation) and the analytic per-chip HBM traffic model."""
from __future__ import annotations

import pytest

from repro.analysis.memtraffic import (activation_traffic_per_layer,
                                       collective_wire_bytes,
                                       flash_kv_traffic, hbm_traffic)
from repro.config.base import ModelConfig
from repro.config.shapes import ShapeConfig


# ---------------------------------------------------- collective wire bytes
def test_ring_model_closed_forms():
    R, g = 1024.0, 8
    assert collective_wire_bytes("all-gather", R, g) == R / g * (g - 1)
    assert collective_wire_bytes("reduce-scatter", R, g) == R * (g - 1)
    assert collective_wire_bytes("all-reduce", R, g) == 2 * R * (g - 1) / g
    assert collective_wire_bytes("all-to-all", R, g) == R * (g - 1) / g
    assert collective_wire_bytes("collective-permute", R, g) == R


def test_allreduce_equals_rs_plus_ag_of_shards():
    # ring AR = ring RS + ring AG over the same g shards; with result size R,
    # the RS leg's result is one R/g shard and the AG leg rebuilds R from it
    R, g = 4096.0, 16
    rs = collective_wire_bytes("reduce-scatter", R / g, g)
    ag = collective_wire_bytes("all-gather", R, g)
    assert collective_wire_bytes("all-reduce", R, g) == pytest.approx(rs + ag)


def test_single_participant_moves_nothing_but_permute_still_pays():
    # g=1: every ring collective is a no-op on the wire; a permute is a
    # point-to-point send of its payload regardless of group bookkeeping
    for kind in ("all-gather", "reduce-scatter", "all-reduce", "all-to-all"):
        assert collective_wire_bytes(kind, 512.0, 1) == 0.0
    assert collective_wire_bytes("collective-permute", 512.0, 1) == 512.0


def test_group_size_clamped_and_unknown_kind_passthrough():
    assert collective_wire_bytes("all-reduce", 100.0, 0) == 0.0
    assert collective_wire_bytes("frob-exchange", 100.0, 8) == 100.0


# ------------------------------------------------------------- HBM traffic
def _tiny_cfg(**kw) -> ModelConfig:
    kw.setdefault("name", "tiny")
    kw.setdefault("family", "dense")
    kw.setdefault("num_layers", 2)
    kw.setdefault("d_model", 64)
    kw.setdefault("num_heads", 4)
    kw.setdefault("num_kv_heads", 4)
    kw.setdefault("d_ff", 256)
    kw.setdefault("vocab_size", 1000)
    return ModelConfig(**kw)


def test_activation_traffic_scales_with_tokens_and_passes():
    cfg = _tiny_cfg()
    one = activation_traffic_per_layer(cfg, tokens_global=1024, chips=4,
                                       passes=1.0)
    assert one > 0
    # linear in tokens-per-chip and in passes
    assert activation_traffic_per_layer(cfg, 2048, 4, 1.0) == 2 * one
    assert activation_traffic_per_layer(cfg, 1024, 8, 1.0) == one / 2
    assert activation_traffic_per_layer(cfg, 1024, 4, 2.0) == 2 * one


def test_flash_kv_traffic_zero_for_ssm_and_windowed():
    shape = ShapeConfig("t", seq_len=8192, global_batch=4, kind="train")
    ssm = _tiny_cfg(family="ssm")
    assert flash_kv_traffic(ssm, shape, chips=4) == 0.0
    full = flash_kv_traffic(_tiny_cfg(), shape, chips=4)
    swa = flash_kv_traffic(_tiny_cfg(sliding_window=1024), shape, chips=4)
    assert 0.0 < swa < full  # a window re-reads fewer K,V bytes


def test_hbm_traffic_train_counts_every_stream():
    cfg = _tiny_cfg()
    shape = ShapeConfig("t", seq_len=1024, global_batch=8, kind="train")
    P, M = 1e6, 2e6
    total = hbm_traffic(cfg, shape, chips=4, param_bytes_chip=P,
                        moment_bytes_chip=M)
    # weights 3P + grads 2P + optimizer (4M + 2P) is the remat floor
    assert total > 7 * P + 4 * M
    no_remat = hbm_traffic(cfg, shape, chips=4, param_bytes_chip=P,
                           moment_bytes_chip=M, remat=False)
    assert total - no_remat == pytest.approx(P)  # remat = one extra read


def test_hbm_traffic_decode_is_params_plus_cache():
    cfg = _tiny_cfg()
    shape = ShapeConfig("d", seq_len=1024, global_batch=8, kind="decode")
    assert hbm_traffic(cfg, shape, chips=4, param_bytes_chip=5.0,
                       cache_bytes_chip=7.0) == 12.0
