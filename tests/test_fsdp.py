"""Layer provenance, reverse-topological bucketing and the FSDP (ZeRO-3)
composition of the explicit grad-sync schedule (core.overlap + models/* +
launch/steps). Multi-device behaviour (real reduce-scatters, channel-order,
memory residency) lives in tests/test_system.py; everything here runs on the
single CPU device."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.overlap import (fsdp_all_gather, fsdp_layout, fsdp_shard_full,
                                fsdp_unshard_full, grad_sync, grad_sync_fsdp,
                                make_buckets)

ARCHS = ["qwen3-8b", "mixtral-8x7b", "mamba2-780m", "recurrentgemma-2b",
         "whisper-base", "llava-next-34b"]


def _model(arch_id: str, scan: bool = True):
    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model

    return build_model(get_arch(arch_id).reduced(),
                       ModelOptions(attn_impl="dense", scan_layers=scan))


# ------------------------------------------------------------ provenance
@pytest.mark.parametrize("arch_id", ARCHS)
@pytest.mark.parametrize("scan", [True, False])
def test_every_param_leaf_carries_a_layer_tag(arch_id, scan):
    """param_layers() mirrors the param tree exactly and every leaf is an int
    forward depth: 0 at the embedding/frontends, the maximum on the head —
    the total order the reverse-topological bucket schedule relies on."""
    model = _model(arch_id, scan)
    params = model.abstract_params()
    layers = model.param_layers()
    assert jax.tree.structure(params) == jax.tree.structure(layers)
    tags = jax.tree.leaves(layers)
    assert tags and all(isinstance(t, int) and t >= 0 for t in tags)
    assert min(tags) == 0 and max(tags) >= 1


def test_layer_tags_order_embed_stack_head():
    model = _model("qwen3-8b", scan=False)
    layers = model.param_layers()
    cfg = model.cfg
    assert layers["embed"] == 0
    depths = sorted({t for t in jax.tree.leaves(layers["layers"])})
    assert depths == list(range(1, cfg.num_layers + 1))  # unrolled: 1..N
    assert layers["final_norm"] == cfg.num_layers + 1
    head = layers.get("lm_head", layers["final_norm"])
    assert head == cfg.num_layers + 1


def test_layer_tags_scanned_stack_is_one_depth():
    """lax.scan's backward releases the whole stacked gradient at once, so
    the scanned stack is ONE subdomain of the layer dimension."""
    model = _model("qwen3-8b", scan=True)
    layers = model.param_layers()
    assert set(jax.tree.leaves(layers["layers"])) == {1}


# ------------------------------------------------- reverse-topo bucketing
def _layered_tree(sizes_by_depth):
    tree, layers = {}, {}
    for d, sizes in sizes_by_depth.items():
        for j, s in enumerate(sizes):
            tree[f"d{d}_{j}"] = jnp.zeros((s,))
            layers[f"d{d}_{j}"] = d
    return tree, layers


def test_make_buckets_layered_partition_and_boundaries():
    """Layer-provenance buckets: every leaf exactly once, cuts ONLY at layer
    boundaries (no layer is split across buckets), emission order deepest
    first."""
    tree, layers = _layered_tree({0: [50, 30], 1: [40], 2: [40, 5],
                                  3: [60], 4: [20, 20]})
    buckets = make_buckets(tree, 3, layers=layers, order="reverse_topo")
    idx2tag = dict(enumerate(jax.tree.leaves(layers)))
    seen = sorted(i for b in buckets for i, _ in b)
    assert seen == list(range(len(idx2tag)))
    tag_sets = [{idx2tag[i] for i, _ in b} for b in buckets]
    for a in range(len(tag_sets)):
        for b in range(a + 1, len(tag_sets)):
            assert not (tag_sets[a] & tag_sets[b]), "layer split across buckets"
    maxes = [max(s) for s in tag_sets]
    assert maxes == sorted(maxes, reverse=True), "not last-backward-first"
    # 'tree' order is the same cut, forward
    fwd = make_buckets(tree, 3, layers=layers, order="tree")
    fmaxes = [max({idx2tag[i] for i, _ in b}) for b in fwd]
    assert fmaxes == sorted(fmaxes)


def test_make_buckets_layered_caps_at_distinct_depths():
    tree, layers = _layered_tree({0: [10], 1: [10]})
    assert len(make_buckets(tree, 8, layers=layers)) == 2


def test_make_buckets_layered_mismatched_provenance_raises():
    tree, layers = _layered_tree({0: [10], 1: [10]})
    layers.pop("d1_0")
    with pytest.raises(ValueError, match="provenance"):
        make_buckets(tree, 2, layers=layers)
    with pytest.raises(ValueError, match="order"):
        make_buckets(tree, 2, layers={k: 0 for k in tree}, order="sideways")


def test_make_buckets_legacy_unchanged_without_layers():
    tree = {f"w{i}": jnp.zeros((s,)) for i, s in enumerate([5, 100, 7, 60])}
    buckets = make_buckets(tree, 2)
    seen = sorted(i for b in buckets for i, _ in b)
    assert seen == [0, 1, 2, 3]
    for b in buckets:
        idxs = [i for i, _ in b]
        assert idxs == sorted(idxs)


# ------------------------------------------------------- zero-leaf guards
def test_grad_sync_empty_tree_emits_no_collective(single_mesh):
    """Zero gradient leaves: both schedules return the tree untouched and the
    lowering contains NO collective (the old two_phase psum'd an empty
    zeros((0,)) — a pointless wire op)."""
    from jax.sharding import PartitionSpec as P

    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            lambda g, mode=mode: grad_sync(g, "data", mode=mode),
            mesh=single_mesh, in_specs=(P(),), out_specs=P()))
        assert f({}) == {}
        txt = f.lower({}).as_text()
        assert "all-reduce" not in txt and "all_reduce" not in txt


# --------------------------------------------------------- ZeRO-3 layout
def _mixed_params():
    k = jax.random.PRNGKey(0)
    tree = {
        "emb": jax.random.normal(k, (7, 6), jnp.float32),          # 42
        "w1": jax.random.normal(jax.random.fold_in(k, 1),
                                (5, 5)).astype(jnp.bfloat16),       # 25
        "n1": jnp.ones((3,), jnp.float32),
        "head": jax.random.normal(jax.random.fold_in(k, 2), (11,)),
    }
    layers = {"emb": 0, "w1": 1, "n1": 1, "head": 2}
    return tree, layers


@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_fsdp_layout_roundtrip_with_padding(n_shards):
    tree, layers = _mixed_params()
    layout = fsdp_layout(tree, n_shards, 3, layers=layers)
    # forward-order buckets, per-dtype buffers, padding to n_shards
    assert [g.bucket for g in layout.groups] == sorted(
        g.bucket for g in layout.groups)
    for g in layout.groups:
        assert g.padded % n_shards == 0 and g.padded - g.size < n_shards
    flat = fsdp_shard_full(tree, layout)
    assert set(flat) == set(layout.keys)
    back = fsdp_unshard_full(flat, layout)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fsdp_gather_scatter_roundtrip_single_device(single_mesh):
    """On an axis of size 1 the ZeRO-3 schedule is the identity: gather(shard)
    == params and the scattered grads reassemble to the plain sync."""
    from jax.sharding import PartitionSpec as P

    tree, layers = _mixed_params()
    layout = fsdp_layout(tree, 1, 3, layers=layers)
    flat = fsdp_shard_full(tree, layout)

    def local(pf):
        p = fsdp_all_gather(pf, layout, "data")
        gf = grad_sync_fsdp(p, layout, "data")   # "grads" := params here
        return p, gf

    p, gf = jax.jit(jax.shard_map(
        local, mesh=single_mesh, in_specs=(P(),), out_specs=P(),
        check_vma=False))(flat)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    back = fsdp_unshard_full(gf, layout)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_grad_sync_fsdp_rejects_foreign_tree():
    tree, layers = _mixed_params()
    layout = fsdp_layout(tree, 1, 3, layers=layers)
    with pytest.raises(ValueError, match="layout"):
        grad_sync_fsdp({"other": jnp.zeros((3,))}, layout, "data")


# ------------------------------------------------- trainer composition
def test_fsdp_trainer_step_matches_replicated_single_device(tmp_path):
    """param_shard=True on a 1-device DP mesh: same losses and params as the
    replicated explicit step. Tolerances are 1-ulp tight, not exact: the
    grad-norm sums per-buffer partials in flat-dict order vs the replicated
    step's tree order, so the clip scale can differ in the last f32 bit
    (the multi-device oracle is the subprocess test in test_system.py)."""
    from repro.config.base import ParallelConfig, RunConfig, TrainConfig
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.runtime.trainer import Trainer

    cfg = get_arch("qwen3-8b").reduced()
    train = TrainConfig(global_batch=2, seq_len=16, warmup_steps=2,
                        total_steps=8, checkpoint_every=10**6,
                        checkpoint_dir=str(tmp_path))
    mesh = make_mesh((1,), ("data",))
    outs = {}
    for name, par in {
        "fsdp": ParallelConfig(param_shard=True, remat="none"),
        "repl": ParallelConfig(param_shard=False, remat="none"),
    }.items():
        t = Trainer(RunConfig(cfg, par, train), mesh=mesh)
        t.train(2)
        outs[name] = (t.full_params(), [m["loss"] for m in t.metrics_log])
    np.testing.assert_allclose(outs["fsdp"][1], outs["repl"][1], rtol=1e-6)
    for a, b in zip(jax.tree.leaves(outs["fsdp"][0]),
                    jax.tree.leaves(outs["repl"][0])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_fsdp_checkpoint_restore_roundtrip(tmp_path):
    """param_shard state checkpoints and restores: the restarted trainer
    resumes from the saved step with identical flat buffers, re-placed on
    their DP shardings (the restore path mirrors fsdp_init_state)."""
    from repro.config.base import ParallelConfig, RunConfig, TrainConfig
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.runtime.trainer import Trainer

    cfg = get_arch("qwen3-8b").reduced()
    train = TrainConfig(global_batch=2, seq_len=16, warmup_steps=2,
                        total_steps=8, checkpoint_every=2,
                        checkpoint_dir=str(tmp_path))
    run = RunConfig(cfg, ParallelConfig(param_shard=True, remat="none"), train)
    mesh = make_mesh((1,), ("data",))
    t1 = Trainer(run, mesh=mesh)
    t1.train(2)   # saves at step 2
    t2 = Trainer(run, mesh=mesh)
    assert t2.restore_if_available() and t2.step == 2
    for k in t1.params:
        np.testing.assert_array_equal(
            np.asarray(t1.params[k], np.float32),
            np.asarray(t2.params[k], np.float32))
        assert t2.params[k].sharding == t1.params[k].sharding
        assert (t2.opt_state["m"][k].sharding
                == t1.opt_state["m"][k].sharding)
    t2.train(1)   # the restored state steps without recompiling surprises
    assert t2.step == 3


def test_param_shard_needs_explicit_mesh():
    """A non-trivial TP axis cannot host the explicit ZeRO-3 step — the
    config error must be loud, not a silent wrong-layout run."""
    from repro.config.base import ParallelConfig
    from repro.launch.steps import fsdp_layout_for

    model = _model("qwen3-8b")
    with pytest.raises(ValueError, match="param_shard"):
        fsdp_layout_for(model, ParallelConfig(param_shard=True), mesh=None)


# ------------------------------------------------------- streaming ZeRO-3
def _streaming_pair():
    """The canonical comparator configs: streaming vs gather-all on the SAME
    per-layer layout, with the model options matched so the two lowerings
    are numerically the same program (unfused xent — the streamed loss uses
    the log_softmax path — and remat='full' on both)."""
    from repro.config.base import ParallelConfig

    stream = ParallelConfig(param_shard=True, fsdp_streaming=True,
                            scan_layers=False, remat="full")
    gather = ParallelConfig(param_shard=True, scan_layers=False,
                            remat="full", bucket_order="layer")
    return stream, gather


def test_fsdp_streaming_config_guards():
    """Streaming forfeits its memory bound under partial remat and has no
    scanned lowering — both must fail loudly at config time."""
    from repro.config.base import ParallelConfig

    with pytest.raises(ValueError, match="remat"):
        ParallelConfig(param_shard=True, fsdp_streaming=True,
                       scan_layers=False, remat="dots")
    with pytest.raises(ValueError, match="scan_layers"):
        ParallelConfig(param_shard=True, fsdp_streaming=True,
                       scan_layers=True, remat="full")
    with pytest.raises(ValueError, match="param_shard"):
        ParallelConfig(param_shard=False, fsdp_streaming=True,
                       scan_layers=False, remat="full")


def test_fsdp_streaming_trainer_bit_identical_to_gather_all(tmp_path):
    """The tentpole contract on one device: the streaming schedule (per-layer
    gather inside each remat region, backward regather) produces BIT-identical
    losses, params and AdamW moments to the top-of-step gather-all step over
    multiple steps. Exact equality, not allclose — streaming only moves WHEN
    buffers are gathered, never what is computed."""
    from repro.config.base import RunConfig, TrainConfig
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.models.model import ModelOptions
    from repro.runtime.trainer import Trainer

    cfg = get_arch("qwen3-8b").reduced()
    train = TrainConfig(global_batch=2, seq_len=16, warmup_steps=2,
                        total_steps=8, checkpoint_every=10**6,
                        checkpoint_dir=str(tmp_path))
    mesh = make_mesh((1,), ("data",))
    opts = ModelOptions(attn_impl="dense", scan_layers=False, remat="full",
                        fused_xent=False)
    spar, gpar = _streaming_pair()
    outs = {}
    for name, par in {"stream": spar, "gather": gpar}.items():
        t = Trainer(RunConfig(cfg, par, train), mesh=mesh, options=opts)
        t.train(3)
        outs[name] = (t.params, t.opt_state,
                      [m["loss"] for m in t.metrics_log])
    assert outs["stream"][2] == outs["gather"][2]
    for k in outs["stream"][0]:
        np.testing.assert_array_equal(
            np.asarray(outs["stream"][0][k], np.float32),
            np.asarray(outs["gather"][0][k], np.float32))
        for mom in ("m", "v"):
            np.testing.assert_array_equal(
                np.asarray(outs["stream"][1][mom][k]),
                np.asarray(outs["gather"][1][mom][k]))


def test_fsdp_sharded_init_bit_identical_to_full_materialize():
    """Per-bucket jitted init (fsdp_init_state) must produce the SAME bits as
    materializing the whole tree eagerly and sharding it — leaf keys derive
    from tree paths, not traversal order, and the optimization_barrier in
    init_leaf pins the eager two-rounding sequence under jit."""
    from repro.config.base import ParallelConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import fsdp_init_state, fsdp_layout_for

    model = _model("qwen3-8b", scan=False)
    par = ParallelConfig(param_shard=True, fsdp_streaming=True,
                         scan_layers=False, remat="full")
    mesh = make_mesh((1,), ("data",))
    rng = jax.random.PRNGKey(7)
    pflat, opt, layout = fsdp_init_state(model, par, mesh, rng)
    full = fsdp_shard_full(model.init(rng), layout)
    assert set(pflat) == set(full)
    for k in pflat:
        np.testing.assert_array_equal(np.asarray(pflat[k], np.float32),
                                      np.asarray(full[k], np.float32))
    for mom in ("m", "v"):
        for k, v in opt[mom].items():
            assert v.dtype == np.float32
            assert not np.asarray(v).any()
    assert int(opt["step"]) == 0


def test_fsdp_streaming_stream_materialize_matches_unshard():
    """FsdpStream.materialize on a single shard reproduces exactly the leaves
    of its depths (None holes elsewhere), matching the full unshard."""
    from repro.config.base import ParallelConfig
    from repro.core.overlap import fsdp_stream
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import fsdp_init_state

    model = _model("qwen3-8b", scan=False)
    par, _ = _streaming_pair()
    mesh = make_mesh((1,), ("data",))
    pflat, _, layout = fsdp_init_state(model, par, mesh,
                                       jax.random.PRNGKey(0))
    stream = fsdp_stream(layout, model.param_layers(), ("data",))
    full = fsdp_unshard_full(pflat, layout)
    depths = stream.depths
    assert depths[0] == 0 and len(depths) == 2 + model.cfg.num_layers

    from jax.sharding import PartitionSpec as P

    got = jax.shard_map(                             # first layer bucket
        lambda flat: stream.materialize(flat, depths[1]),
        mesh=mesh, in_specs=(P("data"),), out_specs=P(),
        check_vma=False)(pflat)
    tags = jax.tree.leaves(model.param_layers())
    for i, (g, w) in enumerate(zip(jax.tree.leaves(full),
                                   jax.tree.leaves(
                                       got, is_leaf=lambda x: x is None))):
        if tags[i] == depths[1]:
            np.testing.assert_array_equal(np.asarray(g, np.float32),
                                          np.asarray(w, np.float32))
        else:
            assert w is None


# --------------------------------------------------- checkpoint re-layout
def test_restore_fsdp_checkpoint_relayout_roundtrip(tmp_path):
    """Portability: a checkpoint written under one FsdpLayout imports under a
    DIFFERENT bucket cut bit-exactly — params AND f32 moments — via the
    unshard-with-old / reshard-with-new path."""
    from repro.checkpoint import restore_fsdp_checkpoint, save_checkpoint
    from repro.config.base import ParallelConfig
    from repro.core.overlap import fsdp_relayout
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import fsdp_init_state, fsdp_layout_for

    model = _model("qwen3-8b", scan=False)
    mesh = make_mesh((1,), ("data",))
    old_par = ParallelConfig(param_shard=True, grad_buckets=2,
                             scan_layers=False)
    new_par = ParallelConfig(param_shard=True, fsdp_streaming=True,
                             scan_layers=False, remat="full")
    pflat, opt, old_layout = fsdp_init_state(model, old_par, mesh,
                                             jax.random.PRNGKey(3))
    new_layout, _ = fsdp_layout_for(model, new_par, mesh)
    assert ({g.key for g in old_layout.groups}
            != {g.key for g in new_layout.groups})
    save_checkpoint(str(tmp_path), 5, {"params": pflat, "opt": opt})

    step, state, _ = restore_fsdp_checkpoint(str(tmp_path), old_layout,
                                             new_layout)
    assert step == 5
    want = fsdp_relayout(pflat, old_layout, new_layout)
    assert set(state["params"]) == {g.key for g in new_layout.groups}
    for k in want:
        np.testing.assert_array_equal(np.asarray(state["params"][k],
                                                 np.float32),
                                      np.asarray(want[k], np.float32))
    for mom in ("m", "v"):
        want_m = fsdp_relayout(opt[mom], old_layout, new_layout)
        for k in want_m:
            assert state["opt"][mom][k].dtype == np.float32
            np.testing.assert_array_equal(np.asarray(state["opt"][mom][k]),
                                          np.asarray(want_m[k]))
    assert int(state["opt"]["step"]) == 0


def test_structural_restore_across_layouts_raises_value_error(tmp_path):
    """Restoring a checkpoint whose flat buffers were cut under a different
    layout must raise a ValueError NAMING both layouts' bucket keys and
    pointing at restore_fsdp_checkpoint — not a bare KeyError."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.config.base import ParallelConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import fsdp_init_state, fsdp_layout_for

    model = _model("qwen3-8b", scan=False)
    mesh = make_mesh((1,), ("data",))
    old_par = ParallelConfig(param_shard=True, grad_buckets=2,
                             scan_layers=False)
    new_par = ParallelConfig(param_shard=True, fsdp_streaming=True,
                             scan_layers=False, remat="full")
    pflat, opt, old_layout = fsdp_init_state(model, old_par, mesh,
                                             jax.random.PRNGKey(3))
    save_checkpoint(str(tmp_path), 1, {"params": pflat, "opt": opt})
    new_layout, _ = fsdp_layout_for(model, new_par, mesh)
    target = {"params": {g.key: jax.ShapeDtypeStruct((g.padded,), g.dtype)
                         for g in new_layout.groups}}
    with pytest.raises(ValueError,
                       match="restore_fsdp_checkpoint") as err:
        restore_checkpoint(str(tmp_path), target)
    for g in new_layout.groups:
        assert g.key in str(err.value)
