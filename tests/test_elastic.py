"""Elastic re-mesh + straggler reassignment (DESIGN §4's 1000-node posture)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest
from _hypothesis_compat import given, settings, st

from repro.runtime.ft import reassign_host_shards

REPO = Path(__file__).resolve().parents[1]


@given(n=st.integers(2, 64), k=st.integers(0, 8))
@settings(max_examples=100, deadline=None)
def test_reassignment_covers_all_slices(n, k):
    failed = list(range(0, min(k, n - 1)))
    plan = reassign_host_shards(n, failed)
    served = sorted(s for slices in plan.values() for s in slices)
    assert served == list(range(n))                 # every slice still served
    assert set(plan) == set(range(n)) - set(failed)  # only survivors serve
    loads = [len(v) for v in plan.values()]
    assert max(loads) - min(loads) <= 1              # balanced


def test_reassignment_all_failed_raises():
    with pytest.raises(RuntimeError):
        reassign_host_shards(4, [0, 1, 2, 3])


def test_reassigned_slices_reproduce_global_batch():
    """Survivors materialize the lost host's slice exactly (stateless data)."""
    import numpy as np

    from repro.data.pipeline import SyntheticLMDataset

    ds = SyntheticLMDataset(vocab_size=97, seq_len=8, global_batch=16, seed=1)
    full = ds.batch_at(5)
    plan = reassign_host_shards(4, failed=[2])
    parts = {}
    for host, slices in plan.items():
        for s in slices:
            parts[s] = ds.host_slice(5, s, 4)
    got = np.concatenate([parts[i]["tokens"] for i in range(4)], axis=0)
    np.testing.assert_array_equal(got, full["tokens"])


@pytest.mark.slow
def test_elastic_restore_onto_smaller_mesh():
    """Train on a (2,2) mesh, checkpoint, lose half the devices, restore onto
    (2,1) and keep training — loss trajectory continues finitely and the
    restored params equal the saved ones."""
    code = """
    import json, dataclasses, numpy as np, jax
    from repro.config.base import ParallelConfig, RunConfig, TrainConfig
    from repro.config.registry import get_arch
    from repro.runtime.trainer import Trainer
    from repro.launch.mesh import make_mesh
    import tempfile, os

    d = tempfile.mkdtemp()
    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(), num_layers=2)
    run = RunConfig(model=cfg, parallel=ParallelConfig(remat="none"),
                    train=TrainConfig(global_batch=4, seq_len=32, lr=5e-3,
                                      warmup_steps=1, total_steps=6,
                                      checkpoint_every=2, checkpoint_dir=d))
    mesh_big = make_mesh((2, 2), ("data", "model"))
    t1 = Trainer(run, mesh=mesh_big)
    t1.train(4)
    w_before = float(np.asarray(jax.tree.leaves(t1.params)[0],
                                np.float32).sum())
    del t1

    mesh_small = make_mesh((2, 1), ("data", "model"))   # lost half the chips
    t2 = Trainer(run, mesh=mesh_small)
    assert t2.restore_if_available()
    assert t2.step == 4
    w_after = float(np.asarray(jax.tree.leaves(t2.params)[0],
                               np.float32).sum())
    t2.train(2)
    print(json.dumps({
        "w_match": abs(w_before - w_after) < 1e-3 * (1 + abs(w_before)),
        "final_loss": t2.metrics_log[-1]["loss"],
    }))
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["w_match"]
    import numpy as np

    assert np.isfinite(r["final_loss"])
