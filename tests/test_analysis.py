"""Roofline machinery: HLO collective parser on real + synthetic modules,
three-term model arithmetic, analytic traffic model, and the k0/k1 layer
extrapolation's exactness."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.hlo import collective_bytes, count_ops, parse_collectives
from repro.analysis.roofline import HW, RooflineReport, model_flops_for

SYNTHETIC_HLO = """
HloModule test
%add { ... }
%x = f32[1024]{0} parameter(0)
%ar = f32[1024]{0} all-reduce(%x), channel_id=1, replica_groups=[4,2]<=[8], to_apply=%add
%ag = bf16[4096,64]{1,0} all-gather(%small), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
%rs = f32[256]{0} reduce-scatter(%big), channel_id=3, replica_groups={{0,1,2,3}}, to_apply=%add
%cp = bf16[2,128]{1,0} collective-permute(%edge), source_target_pairs={{0,1},{1,2}}
%a2a = f32[512]{0} all-to-all(%y), channel_id=4, replica_groups=[1,8]<=[8]
%done = f32[1024]{0} all-reduce-done(%start)
"""


def test_parser_kinds_and_groups():
    s = parse_collectives(SYNTHETIC_HLO)
    kinds = s.by_kind()
    assert set(kinds) == {"all-reduce", "all-gather", "reduce-scatter",
                          "collective-permute", "all-to-all"}
    ops = {o.kind: o for o in s.ops}
    # all-reduce: groups of 2 -> wire = 2*B*(g-1)/g = B
    assert ops["all-reduce"].group_size == 2
    assert ops["all-reduce"].wire_bytes == pytest.approx(1024 * 4)
    # all-gather groups of 4: operand = result/4; wire = 3*operand
    assert ops["all-gather"].group_size == 4
    assert ops["all-gather"].operand_bytes == pytest.approx(4096 * 64 * 2 / 4)
    # reduce-scatter list-form groups {{0,1,2,3}} -> g=4
    assert ops["reduce-scatter"].group_size == 4
    assert ops["reduce-scatter"].wire_bytes == pytest.approx(256 * 4 * 3)
    # -done must not double count
    assert kinds["all-reduce"][0] == 1


def test_parser_on_real_compiled_module(single_mesh):
    """psum on a size-1 axis may fold away, so use a real 2-way reduce via
    two devices? Not available — instead assert the parser returns 0 ops on
    a collective-free module and is robust to its text."""
    f = jax.jit(lambda x: (x @ x).sum())
    txt = f.lower(jnp.ones((64, 64))).compile().as_text()
    assert parse_collectives(txt).ops == []
    assert collective_bytes(txt) == 0.0
    assert count_ops(txt, "fusion") >= 0


def test_roofline_terms_and_dominance():
    hw = HW(peak_flops=100.0, hbm_bw=10.0, ici_bw=1.0)
    r = RooflineReport(arch="a", shape="s", mesh="m", chips=2,
                       hlo_flops=200.0, hlo_bytes=50.0, coll_bytes=1.0,
                       model_flops=300.0, hw=hw)
    assert r.t_comp == pytest.approx(2.0)
    assert r.t_mem == pytest.approx(5.0)
    assert r.t_coll == pytest.approx(1.0)
    assert r.dominant == "memory"
    assert r.t_step_overlapped == pytest.approx(5.0)
    assert r.t_step_two_phase == pytest.approx(6.0)
    assert r.useful_flops_ratio == pytest.approx(300.0 / 400.0)
    # useful time = (300/2)/100 = 1.5 ; fraction = 1.5/5
    assert r.roofline_fraction == pytest.approx(0.3)


def test_model_flops_train_vs_infer():
    assert model_flops_for(10, 7, "train") == 6.0 * 70
    assert model_flops_for(10, 7, "decode") == 2.0 * 70


def test_analytic_traffic_decode_dominated_by_params_and_cache():
    from repro.analysis.memtraffic import hbm_traffic
    from repro.config.registry import get_arch
    from repro.config.shapes import shape_by_name

    cfg = get_arch("qwen3-8b")
    tr = hbm_traffic(cfg, shape_by_name("decode_32k"), 256,
                     param_bytes_chip=64e6, cache_bytes_chip=1e9)
    assert tr == pytest.approx(64e6 + 1e9)


@pytest.mark.slow
def test_layer_extrapolation_exact_on_small_arch(single_mesh):
    """flops(L) extrapolated from (1, 2) unrolled layers equals a true
    4-layer unroll for a uniform stack — the dry-run's §Roofline method."""
    import dataclasses

    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model

    base = get_arch("internlm2-1.8b").reduced()
    opts = ModelOptions(attn_impl="dense", scan_layers=False, remat="none")

    def flops(L):
        cfg = dataclasses.replace(base, num_layers=L)
        m = build_model(cfg, opts)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32),
                 "targets": jax.ShapeDtypeStruct((2, 64), jnp.int32)}
        c = jax.jit(jax.value_and_grad(m.train_loss)).lower(
            m.abstract_params(), batch).compile()
        from repro.compat import cost_analysis_dict

        return cost_analysis_dict(c)["flops"]

    f1, f2, f4 = flops(1), flops(2), flops(4)
    per_layer = f2 - f1
    predicted = f2 + per_layer * (4 - 2)
    # Not bit-exact: XLA-CPU duplicates residual-chain elementwise ops into
    # consumer fusions (quadratic ~b*s*d term — measured +72 adds/layer^2 on
    # this reduced config). At full scale that term is ~1e-5 of the per-layer
    # matmul FLOPs, so the dry-run extrapolation is safe; here allow 2%.
    assert predicted == pytest.approx(f4, rel=2e-2)
