"""Property tests for the hierarchical domain over-decomposition (paper §3.2):
the single partition scheme must tile exactly at every level, and the
boundary/halo accounting must match the paper's published Table 1."""
from __future__ import annotations

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.domain import (Domain, decompose_grid, halo_cells,
                               halo_fraction)

dims = st.integers(min_value=1, max_value=64)
parts = st.integers(min_value=1, max_value=8)


@given(shape=st.tuples(dims, dims), grid=st.tuples(parts, parts))
@settings(max_examples=200, deadline=None)
def test_decompose_exact_tiling(shape, grid):
    """Every cell belongs to exactly one box (disjoint + complete)."""
    boxes = decompose_grid(shape, grid)
    assert len(boxes) == grid[0] * grid[1]
    cover = np.zeros(shape, np.int32)
    for b in boxes:
        cover[b.slices()] += 1
    assert (cover == 1).all()


@given(shape=st.tuples(dims, dims), grid=st.tuples(parts, parts))
@settings(max_examples=100, deadline=None)
def test_balanced_split(shape, grid):
    """Block sizes differ by at most one cell per dimension."""
    boxes = decompose_grid(shape, grid)
    for d in range(2):
        sizes = sorted({b.shape[d] for b in boxes})
        assert sizes[-1] - sizes[0] <= 1


@given(shape=st.tuples(st.integers(8, 64), st.integers(8, 64)),
       pgrid=st.tuples(st.integers(1, 4), st.integers(1, 4)),
       sgrid=st.tuples(st.integers(1, 4), st.integers(1, 4)))
@settings(max_examples=100, deadline=None)
def test_hierarchical_reuse(shape, pgrid, sgrid):
    """Process-level boxes, over-decomposed with the SAME scheme, tile the
    global space exactly (the paper's central claim: one scheme, two levels)."""
    cover = np.zeros(shape, np.int32)
    for dom in Domain.all_ranks(shape, pgrid):
        for sub in dom.over_decompose(sgrid):
            assert dom.box.contains(sub.box)
            cover[sub.box.slices()] += 1
    assert (cover == 1).all()


@given(shape=st.tuples(st.integers(8, 32), st.integers(8, 32)),
       pgrid=st.tuples(st.integers(2, 4), st.integers(2, 4)))
@settings(max_examples=50, deadline=None)
def test_boundary_subdomains(shape, pgrid):
    """A subdomain is boundary iff it touches its domain's edge; the count of
    boundary subdomains in a kxk over-decomposition is the ring k^2-(k-2)^2."""
    dom = Domain.for_rank(shape, pgrid, 0)
    for k in (1, 2, 3):
        if min(dom.box.shape) < k:  # degenerate: empty strips touch the edge
            continue
        subs = dom.over_decompose((k, k))
        n_boundary = sum(1 for s in subs if s.is_boundary())
        assert n_boundary == k * k - max(0, k - 2) ** 2


def test_neighbors_symmetry():
    doms = Domain.all_ranks((16, 16), (4, 4))
    idx = {d.rank_index: d for d in doms}
    for d in doms:
        for (dim, side), nb in d.neighbors().items():
            back = idx[nb].neighbors()[(dim, "lo" if side == "hi" else "hi")]
            assert back == d.rank_index


def test_paper_table1_exact():
    paper = {2: 1.6, 4: 4.7, 8: 10.9, 16: 23.4, 32: 48.4}
    for ranks, pct in paper.items():
        _, _, frac = halo_fraction((128, 128), (ranks, 1), width=1)
        assert round(100 * frac, 1) == pct


@given(width=st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_halo_cells_interior_vs_edge(width):
    """Interior boxes allocate two slabs per decomposed dim, edges one."""
    doms = Domain.all_ranks((64, 64), (4, 1))
    for d in doms:
        expected = width * 64 * (1 if d.rank_index[0] in (0, 3) else 2)
        # dim-1 has no neighbors (undecomposed): restrict accounting to dim 0
        assert halo_cells(d.box, d.global_shape, width, dims=[0]) == expected
