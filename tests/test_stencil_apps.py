"""Paper applications on a single device: schedule equivalence (two_phase ==
hdot numerics — the paper's key safety property), convergence, and physics
sanity for Heat2D / RK3-CREAMS / HPCCG."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.stencil import (heat2d_init, heat2d_solve, hpccg_solve,
                                rk3_solve)


@pytest.fixture(scope="module")
def data_mesh():
    from repro.launch.mesh import make_mesh

    return make_mesh((1,), ("data",))


def test_heat2d_schedules_identical(data_mesh):
    u0 = heat2d_init(64, 64)
    u_tp, r_tp = heat2d_solve(u0, data_mesh, "data", 20, mode="two_phase")
    u_hd, r_hd = heat2d_solve(u0, data_mesh, "data", 20, mode="hdot")
    np.testing.assert_allclose(np.asarray(u_tp), np.asarray(u_hd),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(r_tp), np.asarray(r_hd), rtol=1e-6)


def test_heat2d_residual_decreases(data_mesh):
    u0 = heat2d_init(64, 64)
    _, res = heat2d_solve(u0, data_mesh, "data", 50, mode="hdot")
    res = np.asarray(res)
    assert res[-1] < res[0]
    assert (np.diff(res) <= 1e-7).all()  # Jacobi on Laplace is monotone here


def test_heat2d_jacobi_matches_numpy(data_mesh):
    """One sweep equals the classic 5-point numpy update."""
    u0 = heat2d_init(32, 32)
    u1, _ = heat2d_solve(u0, data_mesh, "data", 1, mode="hdot")
    up = np.pad(np.asarray(u0), 1)
    want = 0.25 * (up[:-2, 1:-1] + up[2:, 1:-1] + up[1:-1, :-2] + up[1:-1, 2:])
    np.testing.assert_allclose(np.asarray(u1), want, rtol=1e-6, atol=1e-7)


def test_rk3_schedules_identical(data_mesh):
    v0 = jax.random.normal(jax.random.PRNGKey(0), (12, 12, 32), jnp.float32)
    v_tp = rk3_solve(v0, data_mesh, "data", 5, dt=0.01, mode="two_phase")
    v_hd = rk3_solve(v0, data_mesh, "data", 5, dt=0.01, mode="hdot")
    np.testing.assert_allclose(np.asarray(v_tp), np.asarray(v_hd),
                               rtol=1e-5, atol=1e-6)


def test_rk3_diffusion_smooths(data_mesh):
    """Periodic diffusion preserves the mean and contracts the variance."""
    v0 = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 64), jnp.float32)
    v = rk3_solve(v0, data_mesh, "data", 20, dt=0.01, mode="hdot")
    v0n, vn = np.asarray(v0), np.asarray(v)
    assert vn.std() < v0n.std()
    np.testing.assert_allclose(vn.mean(), v0n.mean(), atol=1e-4)


def test_hpccg_converges_and_schedules_match(data_mesh):
    b = jax.random.normal(jax.random.PRNGKey(2), (16, 16, 16), jnp.float32)
    x_tp, h_tp = hpccg_solve(b, data_mesh, "data", 30, mode="two_phase")
    x_hd, h_hd = hpccg_solve(b, data_mesh, "data", 30, mode="hdot")
    np.testing.assert_allclose(np.asarray(h_tp), np.asarray(h_hd), rtol=1e-4)
    h = np.asarray(h_hd)
    assert h[-1] < 1e-3 * h[0]  # CG on the SPD 27-point system converges fast


def test_hpccg_solution_solves_system(data_mesh):
    """A x ~= b for the returned x (matvec applied via the same operator)."""
    from repro.core.stencil import _stencil27_matvec

    b = jax.random.normal(jax.random.PRNGKey(3), (12, 12, 12), jnp.float32)
    x, _ = hpccg_solve(b, data_mesh, "data", 60, mode="hdot")
    Ax = _stencil27_matvec(x, None, "hdot")
    rel = float(jnp.linalg.norm(Ax - b) / jnp.linalg.norm(b))
    assert rel < 1e-3
