"""The training driver end-to-end for EVERY assigned arch (reduced configs,
2 steps) — locks in the frontend-stub augmentation and per-arch checkpoint
namespacing."""
from __future__ import annotations

import numpy as np
import pytest

from repro.config.registry import list_archs


@pytest.mark.slow
@pytest.mark.parametrize("arch", list_archs())
def test_driver_two_steps_every_arch(arch, tmp_path):
    from repro.launch.train import build_run
    from repro.runtime.trainer import Trainer

    run = build_run(arch, reduced=True, steps=2, global_batch=2, seq_len=32,
                    checkpoint_dir=str(tmp_path))
    t = Trainer(run)
    t.train(2)
    assert len(t.metrics_log) == 2
    assert np.isfinite(t.metrics_log[-1]["loss"])


def test_checkpoint_dirs_namespaced(tmp_path):
    from repro.launch.train import build_run

    r1 = build_run("whisper-base", checkpoint_dir=str(tmp_path))
    r2 = build_run("mamba2-780m", checkpoint_dir=str(tmp_path))
    assert r1.train.checkpoint_dir != r2.train.checkpoint_dir
