"""Benchmark plumbing: subprocess workers with their own device counts.

Multi-device benches re-exec themselves with XLA_FLAGS set (the dry-run rule:
never force device counts globally — pytest and single-device benches must
see 1 CPU device).
"""
from __future__ import annotations

import json
import math
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, List

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "bench"


def parse_mesh_shape(mesh_shape: str) -> tuple:
    """'RxC' -> (R, C), 'PxRxC' -> (P, R, C): the 2-D (rows x cols) and 3-D
    (planes x rows x cols) benchmark topologies."""
    parts = tuple(int(s) for s in mesh_shape.split("x"))
    assert len(parts) in (2, 3) and all(p >= 1 for p in parts), mesh_shape
    return parts


def mesh_devices(mesh_shape: str) -> int:
    return math.prod(parse_mesh_shape(mesh_shape))


def env_info() -> Dict[str, Any]:
    """Provenance stamped onto every worker record (and threaded into the
    committed BENCH_quick.json rows): artifacts from different CI runners are
    only comparable if the toolchain and device count are recorded."""
    import jax

    return {"jax_version": jax.__version__,
            "device_count": jax.device_count()}


def run_worker(module: str, devices: int, args: List[str],
               timeout: int = 1200) -> Dict[str, Any]:
    """Run ``python -m <module> --worker <args>`` with `devices` host devices;
    the worker prints one JSON line on stdout (last line)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    out = subprocess.run(
        [sys.executable, "-m", module, "--worker", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)
    if out.returncode != 0:
        raise RuntimeError(f"worker {module} failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def emit(obj: Dict[str, Any]) -> None:
    """Worker-side: print the result record as the last stdout line, stamped
    with the worker's toolchain/device provenance (:func:`env_info`)."""
    rec = env_info()
    rec.update(obj)  # the worker's own keys win on collision
    print(json.dumps(rec))


def save(name: str, record: Dict[str, Any]) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(record, indent=1))
    return p


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) (jax results block_until_ready'd)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
