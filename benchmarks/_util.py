"""Benchmark plumbing: subprocess workers with their own device counts.

Multi-device benches re-exec themselves with XLA_FLAGS set (the dry-run rule:
never force device counts globally — pytest and single-device benches must
see 1 CPU device).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

REPO = Path(__file__).resolve().parents[1]
RESULTS = REPO / "results" / "bench"


def parse_mesh_shape(mesh_shape: str) -> tuple:
    """'RxC' -> (R, C) for the 2-D (rows x cols) benchmark topologies."""
    r, c = (int(s) for s in mesh_shape.split("x"))
    assert r >= 1 and c >= 1, mesh_shape
    return r, c


def run_worker(module: str, devices: int, args: List[str],
               timeout: int = 1200) -> Dict[str, Any]:
    """Run ``python -m <module> --worker <args>`` with `devices` host devices;
    the worker prints one JSON line on stdout (last line)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = f"{REPO / 'src'}:{REPO}"
    out = subprocess.run(
        [sys.executable, "-m", module, "--worker", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=REPO)
    if out.returncode != 0:
        raise RuntimeError(f"worker {module} failed:\n{out.stderr[-2000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def emit(obj: Dict[str, Any]) -> None:
    """Worker-side: print the result record as the last stdout line."""
    print(json.dumps(obj))


def save(name: str, record: Dict[str, Any]) -> Path:
    RESULTS.mkdir(parents=True, exist_ok=True)
    p = RESULTS / f"{name}.json"
    p.write_text(json.dumps(record, indent=1))
    return p


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds of fn(*args) (jax results block_until_ready'd)."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
