"""Paper Table 4: CREAMS (RK3 + 8th-order stencils) hybrid vs pure-MPI gain.

The paper's Sod-tube domain is 20x20x7000 decomposed along z; the hybrid gain
grows from +2.6% (1 node) to +13.3% (16 nodes) because the HDOT schedule
hides the halo exchange behind the per-direction stencil tasks.

Here: rk3_solve (8th-order, width-4 halos, Williamson RK3 — core/stencil) on
1..8 virtual devices, both schedules; wall clock + per-step collective wire
bytes. The x/y stencils are the "other tasks" that hide the z-halo ppermute,
exactly Figure 5's dependency graph. ``--mesh RxC`` switches to the 2-D
(y, z) grid-mesh decomposition (stage-carried halos on BOTH axes; the y
extent is scaled with the row count so every shard keeps the width-4
pipelined path alive).
"""
from __future__ import annotations

import argparse
from typing import Any, Dict


def worker(devices: int, nz: int, steps: int,
           mesh_shape: str = "") -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks._util import parse_mesh_shape, timeit
    from repro.analysis.hlo import parse_collectives
    from repro.core.stencil import rk3_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh

    if mesh_shape:
        ry, rz = parse_mesh_shape(mesh_shape)  # RK3's grid mesh is (y, z)
        assert ry * rz == devices, (mesh_shape, devices)
        mesh = make_grid_mesh(ry, rz)
        axis = ("rows", "cols")
        # >= 32 y-cells per row shard keeps the width-4 pipelined path alive
        shape = (20, 32 * ry, nz)
    else:
        mesh = make_mesh((devices,), ("data",))
        axis = ("data",)
        # paper: 20 x 20 x 7000; scaled-down x/y for CPU wall clock
        shape = (20, 20, nz)
    key = jax.random.PRNGKey(0)
    v0 = jax.random.normal(key, shape, jnp.float32)
    out: Dict[str, Any] = {"devices": devices, "nz": nz, "steps": steps}
    if mesh_shape:
        out["mesh_shape"] = mesh_shape
    results = {}
    for mode in ("two_phase", "hdot"):
        def solve(v0=v0, mode=mode):
            return rk3_solve(v0, mesh, axis, steps, mode=mode)

        sec = timeit(solve)
        results[mode] = np.asarray(solve())
        lowered = jax.jit(
            lambda v: rk3_solve(v, mesh, axis, 1, mode=mode)).lower(v0)
        coll = parse_collectives(lowered.compile().as_text())
        out[mode] = {"seconds": sec, "steps_per_s": steps / sec,
                     "coll_ops_per_step": len(coll.ops),
                     "coll_wire_bytes_per_step": coll.total_wire_bytes}
    out["numerics_identical"] = bool(
        np.allclose(results["two_phase"], results["hdot"], rtol=2e-5, atol=2e-5))
    out["gain_pct"] = 100.0 * (out["two_phase"]["seconds"]
                               / out["hdot"]["seconds"] - 1.0)
    return out


def run(sizes=(1, 2, 4, 8), nz: int = 1024, steps: int = 10,
        mesh_shapes=()) -> Dict[str, Any]:
    from benchmarks._util import mesh_devices, run_worker

    rows = [run_worker("benchmarks.table4_creams", d,
                       ["--devices", str(d), "--nz", str(nz),
                        "--steps", str(steps)])
            for d in sizes]
    for ms in mesh_shapes:
        d = mesh_devices(ms)
        rows.append(run_worker("benchmarks.table4_creams", d,
                               ["--devices", str(d), "--nz", str(nz),
                                "--steps", str(steps), "--mesh", ms]))
    return {"table": "paper Table 4 (CREAMS RK3)", "rows": rows,
            "paper_gain_pct": {1: 2.58, 2: 3.13, 4: 5.94, 8: 9.97, 16: 13.33}}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--nz", type=int, default=1024)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--mesh", type=str, default="",
                    help="RxC 2-D (y,z) process mesh; empty = z slabs")
    args = ap.parse_args()
    if args.worker:
        from benchmarks._util import emit

        emit(worker(args.devices, args.nz, args.steps, args.mesh))
        return
    rec = run()
    for r in rec["rows"]:
        print(f"devices={r['devices']} mesh={r.get('mesh_shape', '-'):>5s} "
              f"two_phase={r['two_phase']['steps_per_s']:7.2f}/s "
              f"hdot={r['hdot']['steps_per_s']:7.2f}/s gain={r['gain_pct']:+6.2f}% "
              f"identical={r['numerics_identical']}")


if __name__ == "__main__":
    main()
