"""CI gate on the committed overlap trajectory.

Reads BENCH_quick.json (as written by ``python -m benchmarks.run --quick``)
and FAILS (exit 1) when any suite's headline ratio (``hdot_two_phase_ratio*``
per topology, plus lm_step's ZeRO-3 ``fsdp_two_phase_ratio``) drops below
``--min-ratio`` — i.e. when an HDOT schedule has become slower than the
two-phase baseline it exists to beat. The ``moe`` suite's headline is the
capacity-chunked a2a_scan (moe_a2a_chunks=2) vs monolithic dispatch/combine
ratio, gated exactly like the halo/grad-sync suites. Suites that errored
fail the gate outright.

The ``fsdp_mem`` suite (streaming ZeRO-3 memory probe) carries its own
gates, independent of ``--min-ratio``: ``mem_saving_ratio`` must exceed 1
(the streaming schedule's peak live param bytes strictly below the
gather-all peak), every row's streaming peak must sit within
shard + fsdp_working_set bucket widths, and the two schedules' losses must
be bit-identical.

Run:  python -m benchmarks.ci_gate [--min-ratio 1.0] [--path BENCH_quick.json]
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from benchmarks._util import REPO

HEADLINE_KEYS = ("hdot_two_phase_ratio", "hdot_two_phase_ratio_2d",
                 "hdot_two_phase_ratio_3d", "fsdp_two_phase_ratio")


def check(quick: dict, min_ratio: float) -> list:
    """Returns a list of human-readable violations (empty == gate passes)."""
    bad = []
    for suite, rec in quick.items():
        if "error" in rec:
            bad.append(f"{suite}: suite errored: {rec['error']}")
            continue
        for key in HEADLINE_KEYS:
            if key in rec and rec[key] < min_ratio:
                bad.append(f"{suite}.{key} = {rec[key]:.3f} < {min_ratio}")
        # streaming ZeRO-3 memory headline is gated on its own invariant,
        # independent of --min-ratio: the streaming peak must sit strictly
        # below the gather-all peak (ratio > 1), or streaming is pointless
        if "mem_saving_ratio" in rec and rec["mem_saving_ratio"] <= 1.0:
            bad.append(f"{suite}.mem_saving_ratio = "
                       f"{rec['mem_saving_ratio']:.3f} <= 1.0 — streaming "
                       "peak live bytes is not below gather-all")
        for row in rec.get("rows", []):
            if row.get("loss_bit_equal") is False:
                bad.append(f"{suite}: streaming loss is not bit-identical "
                           "to gather-all")
            if row.get("within_working_set_bound") is False:
                bad.append(f"{suite}: streaming peak exceeds shard + "
                           "fsdp_working_set buckets")
    return bad


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--min-ratio", type=float, default=1.0,
                    help="fail when any headline hdot/two_phase ratio is "
                         "below this (default 1.0: hdot must not lose)")
    ap.add_argument("--path", type=Path, default=REPO / "BENCH_quick.json")
    args = ap.parse_args()
    quick = json.loads(args.path.read_text())
    for suite, rec in sorted(quick.items()):
        heads = {k: round(rec[k], 3)
                 for k in HEADLINE_KEYS + ("mem_saving_ratio",) if k in rec}
        print(f"[ci_gate] {suite}: {heads or rec.get('error', 'no rows')}")
    bad = check(quick, args.min_ratio)
    if bad:
        print("[ci_gate] FAIL — hdot schedule regressed vs two_phase:")
        for b in bad:
            print(f"[ci_gate]   {b}")
        return 1
    print(f"[ci_gate] OK — all headline ratios >= {args.min_ratio}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
