"""Paper Tables 2-3 / Figure 4: Heat2D under the two schedules.

The paper measures MPI+OmpSs-2 (HDOT) vs MPI+OpenMP (two-phase) vs pure MPI on
1..32 MareNostrum nodes. Here the process level is a CPU device mesh (1..8
virtual devices in subprocess workers); we measure:

  * wall-clock per sweep for two_phase vs hdot (identical numerics asserted),
  * per-step collective wire bytes + op count parsed from the compiled HLO
    (the structural difference: per-boundary-strip ppermutes in the dataflow
    vs whole-tensor exchange at the phase boundary),
  * the roofline-model step bound for both schedules on the paper's own
    problem scaled to TPU constants (t_two_phase = t_comp + t_coll;
    t_hdot = max(t_comp, t_coll)) — reproducing the paper's *shape* of the
    scaling curve (Figure 2) from first principles.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict


def worker(devices: int, n: int, iters: int,
           mesh_shape: str = "") -> Dict[str, Any]:
    import jax

    from benchmarks._util import parse_mesh_shape, timeit
    from repro.analysis.hlo import parse_collectives
    from repro.core.stencil import heat2d_init, heat2d_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh

    assert len(jax.devices()) == devices, (len(jax.devices()), devices)
    if mesh_shape:
        rows_, cols_ = parse_mesh_shape(mesh_shape)
        assert rows_ * cols_ == devices, (mesh_shape, devices)
        mesh = make_grid_mesh(rows_, cols_)
        axis = ("rows", "cols")
    else:
        mesh = make_mesh((devices,), ("data",))
        axis = ("data",)
    u0 = heat2d_init(n, n)
    out: Dict[str, Any] = {"devices": devices, "n": n, "iters": iters}
    if mesh_shape:
        out["mesh_shape"] = mesh_shape
    results = {}
    for mode in ("two_phase", "hdot"):
        def solve(u0=u0, mode=mode):
            return heat2d_solve(u0, mesh, axis, iters, mode=mode)

        sec = timeit(solve)
        u, res = solve()
        results[mode] = u
        lowered = jax.jit(
            lambda u: heat2d_solve(u, mesh, axis, 1, mode=mode)).lower(u0)
        coll = parse_collectives(lowered.compile().as_text())
        out[mode] = {
            "seconds": sec,
            "sweeps_per_s": iters / sec,
            "final_residual": float(res[-1]),
            "coll_ops_per_sweep": len(coll.ops),
            "coll_wire_bytes_per_sweep": coll.total_wire_bytes,
        }
    import numpy as np
    out["numerics_identical"] = bool(
        np.allclose(np.asarray(results["two_phase"], np.float32),
                    np.asarray(results["hdot"], np.float32),
                    rtol=1e-6, atol=1e-6))
    return out


def run(sizes=(1, 2, 4, 8), n: int = 1024, iters: int = 50,
        mesh_shapes=()) -> Dict[str, Any]:
    """`sizes` drives the legacy 1-D slab rows; `mesh_shapes` — "RxC"
    strings — adds 2-D (rows x cols) block-decomposition rows, so the 2x2 vs
    4x1 overlap gap is a tracked trajectory."""
    from benchmarks._util import parse_mesh_shape, run_worker

    rows = [run_worker("benchmarks.table2_heat2d", d,
                       ["--devices", str(d), "--n", str(n),
                        "--iters", str(iters)])
            for d in sizes]
    for ms in mesh_shapes:
        r_, c_ = parse_mesh_shape(ms)
        rows.append(run_worker("benchmarks.table2_heat2d", r_ * c_,
                               ["--devices", str(r_ * c_), "--n", str(n),
                                "--iters", str(iters), "--mesh", ms]))
    base = rows[0]
    for r in rows:
        for mode in ("two_phase", "hdot"):
            r[mode]["speedup_vs_1dev"] = (
                r[mode]["sweeps_per_s"] / base[mode]["sweeps_per_s"])
    return {"table": "paper Tables 2-3 (Heat2D schedules)", "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--iters", type=int, default=50)
    ap.add_argument("--mesh", type=str, default="",
                    help="RxC 2-D process mesh (e.g. 2x2); empty = 1-D slabs")
    args = ap.parse_args()
    if args.worker:
        from benchmarks._util import emit

        emit(worker(args.devices, args.n, args.iters, args.mesh))
        return
    rec = run()
    for r in rec["rows"]:
        tp, hd = r["two_phase"], r["hdot"]
        print(f"devices={r['devices']} mesh={r.get('mesh_shape', '-'):>5s} "
              f"two_phase={tp['sweeps_per_s']:8.1f}/s "
              f"hdot={hd['sweeps_per_s']:8.1f}/s "
              f"coll(tp)={tp['coll_ops_per_sweep']} coll(hdot)={hd['coll_ops_per_sweep']} "
              f"identical={r['numerics_identical']}")


if __name__ == "__main__":
    main()
