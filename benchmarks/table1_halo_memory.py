"""Paper Table 1: halo memory overhead of the 2-D Gauss-Seidel domain as the
rank count grows (128x128 grid, horizontal 1-D decomposition, halo width 1).

Pure domain arithmetic via repro.core.domain — the same code path the apps
use — checked against the paper's published percentages.
"""
from __future__ import annotations

from typing import Any, Dict

PAPER = {2: 1.6, 4: 4.7, 8: 10.9, 16: 23.4, 32: 48.4}  # % of data in halo


def run() -> Dict[str, Any]:
    from repro.core.domain import halo_fraction

    rows = []
    for ranks, paper_pct in PAPER.items():
        data, halo, frac = halo_fraction((128, 128), (ranks, 1), width=1)
        rows.append({
            "ranks": ranks, "local_data": data, "halo_cells": halo,
            "halo_pct": round(100 * frac, 1), "paper_pct": paper_pct,
            "match": abs(100 * frac - paper_pct) < 0.1,
        })
    return {"table": "paper Table 1", "rows": rows,
            "all_match": all(r["match"] for r in rows)}


def main() -> None:
    rec = run()
    for r in rec["rows"]:
        print(f"ranks={r['ranks']:3d} halo={r['halo_pct']:5.1f}% "
              f"paper={r['paper_pct']:5.1f}% match={r['match']}")
    print("all_match:", rec["all_match"])


if __name__ == "__main__":
    main()
