"""Dynamic load balancing: skewed-cost Heat2D drill, static vs re-cut.

The live straggler drill from :mod:`repro.runtime.rebalance`: `workers`
processes each own one row band of a Jacobi grid and one of them runs
`slow_factor`x slower per cell. The static uniform cut is the two-phase
analogue — every step waits for the straggler — and fills the row's
``two_phase`` slot; the measured-cost dynamic re-cut (per-worker rate EMAs ->
weighted :func:`repro.core.domain.part_extents` every `rebalance_every`
steps) fills ``hdot``. The headline ratio is therefore the throughput
recovered by re-cutting, tracked across PRs like every other schedule gap.

Rows run under a single jax device (``devices: 1``): the parallelism here is
OS processes (recorded as ``workers``), not jax devices — the drill is the
multi-host story, the ``heat2d_weighted`` lint target is the jit story.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict


def worker(workers: int, rows: int, cols: int, steps: int, warmup: int,
           rebalance_every: int, slow_factor: float) -> Dict[str, Any]:
    from repro.runtime.rebalance import straggler_drill_compare

    r = straggler_drill_compare(
        workers=workers, rows=rows, cols=cols, steps=steps, warmup=warmup,
        rebalance_every=rebalance_every, slow_worker=0,
        slow_factor=slow_factor)
    st, dy = r["static"], r["dynamic"]
    return {
        "devices": 1, "workers": workers, "grid": [rows, cols],
        "steps": steps, "slow_factor": slow_factor,
        "two_phase": {"steps_per_s": st["steps_per_s"]},
        "hdot": {"steps_per_s": dy["steps_per_s"],
                 "recuts": len(dy["cut_history"]) - 1,
                 "final_extents": list(dy["extents"])},
        "numerics_identical": bool(st["max_err"] < 1e-6
                                   and dy["max_err"] < 1e-6),
    }


def run(configs=((4, 3.0), (4, 5.0)), rows: int = 64, cols: int = 64,
        steps: int = 24, warmup: int = 4,
        rebalance_every: int = 4) -> Dict[str, Any]:
    """`configs` is a sequence of (workers, slow_factor) pairs — one row
    each. The per-cell cost is sleep-dominated (repro.runtime.rebalance), so
    the rows are CI-stable."""
    from benchmarks._util import run_worker

    rows_out = []
    for workers, slow in configs:
        rows_out.append(run_worker(
            "benchmarks.rebalance", 1,
            ["--workers", str(workers), "--rows", str(rows),
             "--cols", str(cols), "--steps", str(steps),
             "--warmup", str(warmup),
             "--rebalance-every", str(rebalance_every),
             "--slow-factor", str(slow)]))
    return {"table": "dynamic re-partitioning (straggler drill)",
            "rows": rows_out}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rows", type=int, default=64)
    ap.add_argument("--cols", type=int, default=64)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--warmup", type=int, default=4)
    ap.add_argument("--rebalance-every", type=int, default=4)
    ap.add_argument("--slow-factor", type=float, default=3.0)
    args = ap.parse_args()
    if args.worker:
        from benchmarks._util import emit

        emit(worker(args.workers, args.rows, args.cols, args.steps,
                    args.warmup, args.rebalance_every, args.slow_factor))
        return
    rec = run()
    for r in rec["rows"]:
        tp, hd = r["two_phase"], r["hdot"]
        print(f"workers={r['workers']} slow={r['slow_factor']}x "
              f"static={tp['steps_per_s']:6.1f}/s "
              f"dynamic={hd['steps_per_s']:6.1f}/s "
              f"recuts={hd['recuts']} identical={r['numerics_identical']}")


if __name__ == "__main__":
    main()
