"""Paper §4.3 / Figure 8: HPCCG conjugate gradient, taskified.

The paper taskifies ddot (subdomain reduction partials + MPI_Allreduce),
waxpby and the nested sparsemv. Here: CG on the 27-point operator
(core/stencil.hpccg_solve) under three process topologies — z-stacked slabs,
2-D (y, z) row blocks, and HPCCG's native 3-D (x, y, z) mesh (``--mesh
PxRxC``), the corner couplings riding the sequential face-message chain —
both schedules;
convergence is schedule-invariant (asserted) and the collective structure
(2 ddot allreduces + 1 halo exchange per iteration — CG's well-known pattern)
is parsed from the compiled HLO.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict


def worker(devices: int, n: int, iters: int,
           mesh_shape: str = "") -> Dict[str, Any]:
    import math

    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks._util import parse_mesh_shape, timeit
    from repro.analysis.hlo import parse_collectives
    from repro.core.stencil import hpccg_solve
    from repro.launch.mesh import make_grid_mesh, make_mesh

    if mesh_shape:
        parts = parse_mesh_shape(mesh_shape)
        assert math.prod(parts) == devices, (mesh_shape, devices)
        mesh = make_grid_mesh(*parts)
        if len(parts) == 2:          # 2-D row-block (y, z) decomposition
            axis = ("rows", "cols")
            grid = [n, n * parts[0], n * parts[1]]
        else:                        # HPCCG's native 3-D (x, y, z) mesh
            axis = ("planes", "rows", "cols")
            grid = [n * p for p in parts]
    else:
        mesh = make_mesh((devices,), ("data",))
        axis = ("data",)
        grid = [n, n, n * devices]
    key = jax.random.PRNGKey(0)
    b = jax.random.normal(key, tuple(grid), jnp.float32)
    out: Dict[str, Any] = {"devices": devices, "grid": grid, "iters": iters}
    if mesh_shape:
        out["mesh_shape"] = mesh_shape
    hists = {}
    for mode in ("two_phase", "hdot"):
        def solve(b=b, mode=mode):
            return hpccg_solve(b, mesh, axis, iters, mode=mode)

        sec = timeit(solve)
        x, hist = solve()
        hists[mode] = np.asarray(hist)
        lowered = jax.jit(
            lambda b: hpccg_solve(b, mesh, axis, 1, mode=mode)).lower(b)
        coll = parse_collectives(lowered.compile().as_text())
        out[mode] = {"seconds": sec, "iters_per_s": iters / sec,
                     "coll_ops": len(coll.ops),
                     "final_residual": float(hists[mode][-1]),
                     "residual_drop": float(hists[mode][0] / hists[mode][-1])}
    out["convergence_identical"] = bool(
        np.allclose(hists["two_phase"], hists["hdot"], rtol=1e-4))
    return out


def run(sizes=(1, 2, 4, 8), n: int = 48, iters: int = 25,
        mesh_shapes=()) -> Dict[str, Any]:
    from benchmarks._util import mesh_devices, run_worker

    rows = [run_worker("benchmarks.hpccg", d,
                       ["--devices", str(d), "--n", str(n),
                        "--iters", str(iters)])
            for d in sizes]
    for ms in mesh_shapes:
        d = mesh_devices(ms)
        rows.append(run_worker("benchmarks.hpccg", d,
                               ["--devices", str(d), "--n", str(n),
                                "--iters", str(iters), "--mesh", ms]))
    return {"table": "paper §4.3 (HPCCG CG)", "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--iters", type=int, default=25)
    ap.add_argument("--mesh", type=str, default="",
                    help="RxC 2-D (y,z) or PxRxC 3-D (x,y,z) process mesh; "
                         "empty = z slabs")
    args = ap.parse_args()
    if args.worker:
        from benchmarks._util import emit

        emit(worker(args.devices, args.n, args.iters, args.mesh))
        return
    rec = run()
    for r in rec["rows"]:
        tp, hd = r["two_phase"], r["hdot"]
        print(f"devices={r['devices']} mesh={r.get('mesh_shape', '-'):>5s} "
              f"two_phase={tp['iters_per_s']:7.2f}it/s "
              f"hdot={hd['iters_per_s']:7.2f}it/s "
              f"resid_drop={hd['residual_drop']:9.1f} "
              f"conv_identical={r['convergence_identical']}")


if __name__ == "__main__":
    main()
