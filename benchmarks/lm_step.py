"""LM train-step bench: the HDOT gradient-reduction schedule vs two-phase.

The paper's halo exchange maps onto gradient synchronization for LM training
(DESIGN §2): two-phase = one monolithic flattened all-reduce after the whole
backward; HDOT = layer-boundary per-bucket reductions emitted last-backward-
first, free to interleave with backward compute. Measured on N virtual
devices with a reduced qwen3-8b under shard_map (manual DP), plus collective
structure from the compiled HLO.

The `fsdp` row is the ZeRO-3 composition of the same schedule: params live as
bucket-wise flat shards (1/devices residency), all-gathered forward-order at
the top of the step and reduce-scattered reverse-topologically in the
backward — same loss/backward as the other modes, so the ratio tracks what
the bucket-wise gather/scatter costs over the replicated bucketed sync.

The `moe` suite (``--moe``) applies the same two-schedule comparison to the
expert-parallel MoE dispatch: two_phase = monolithic dispatch/combine
all-to-alls (moe_a2a_chunks=1), hdot = the capacity-chunked a2a_scan double
buffer (moe_a2a_chunks=2) where the slice-k+1 dispatch streams while the
slice-k expert FFN computes. Full qwen3-moe reduced train step on a
(1, devices) ("data", "model") mesh — every device in one EP group.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict


def worker(devices: int, steps: int) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from benchmarks._util import timeit
    from repro.analysis.hlo import parse_collectives
    from repro.config.registry import get_arch
    from repro.core.overlap import (fsdp_all_gather, fsdp_layout,
                                    fsdp_shard_full, grad_sync,
                                    grad_sync_fsdp)
    from repro.launch.mesh import make_mesh
    from repro.models.model import ModelOptions, build_model

    mesh = make_mesh((devices,), ("data",))
    cfg = get_arch("qwen3-8b").reduced()
    # fused_xent=False: this bench differentiates through shard_map manual
    # axes where the custom-VJP cotangent vma check rejects the fused tail
    model = build_model(cfg, ModelOptions(attn_impl="dense", fused_xent=False))
    params = model.init(jax.random.PRNGKey(0))
    B, S = 4 * devices, 128
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    out: Dict[str, Any] = {"devices": devices, "arch": cfg.name,
                           "batch": B, "seq": S}
    layers = model.param_layers()
    grads_by_mode = {}
    for mode in ("two_phase", "hdot"):
        def step(params, batch, mode=mode):
            def local(p, b):
                loss, g = jax.value_and_grad(model.train_loss)(p, b)
                g = grad_sync(g, "data", mode=mode, num_buckets=8,
                              layers=layers)
                return jax.lax.pmean(loss, "data"), g

            return jax.shard_map(
                local, mesh=mesh,
                in_specs=(P(), P("data")),
                out_specs=(P(), P()))(params, batch)

        f = jax.jit(step)
        sec = timeit(f, params, batch)
        loss, g = f(params, batch)
        grads_by_mode[mode] = g
        coll = parse_collectives(f.lower(params, batch).compile().as_text())
        out[mode] = {"seconds": sec, "steps_per_s": 1.0 / sec,
                     "loss": float(loss),
                     "allreduce_ops": coll.by_kind().get("all-reduce", (0, 0))[0],
                     "wire_bytes": coll.total_wire_bytes}
    def trees_close(a, b):
        return bool(all(
            np.allclose(np.asarray(x, np.float32), np.asarray(y, np.float32),
                        rtol=1e-5, atol=1e-5)
            for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))))

    out["grads_identical"] = trees_close(grads_by_mode["two_phase"],
                                         grads_by_mode["hdot"])

    # ZeRO-3 composition: bucket-wise AG (forward order) + RS (reverse-topo),
    # same loss/backward — params enter as 1/devices flat shards
    layout = fsdp_layout(params, devices, 8, layers=layers)
    pflat = {k: jax.device_put(
        v, jax.sharding.NamedSharding(mesh, P("data")))
        for k, v in fsdp_shard_full(params, layout).items()}
    flat_specs = {k: P("data") for k in layout.keys}

    def step_fsdp(pflat, batch):
        def local(pf, b):
            p = fsdp_all_gather(pf, layout, "data")
            loss, g = jax.value_and_grad(model.train_loss)(p, b)
            gf = grad_sync_fsdp(g, layout, "data")
            return jax.lax.pmean(loss, "data"), gf

        return jax.shard_map(
            local, mesh=mesh, in_specs=(flat_specs, P("data")),
            out_specs=(P(), flat_specs), check_vma=False)(pflat, batch)

    f = jax.jit(step_fsdp)
    sec = timeit(f, pflat, batch)
    loss, gf = f(pflat, batch)
    coll = parse_collectives(f.lower(pflat, batch).compile().as_text())
    kinds = coll.by_kind()
    out["fsdp"] = {"seconds": sec, "steps_per_s": 1.0 / sec,
                   "loss": float(loss),
                   "reduce_scatter_ops": kinds.get("reduce-scatter", (0, 0))[0],
                   "all_gather_ops": kinds.get("all-gather", (0, 0))[0],
                   "wire_bytes": coll.total_wire_bytes}
    # the scattered grad shards, reassembled, must equal the hdot/two_phase
    # full sync on EVERY leaf (the same sum, reduce-scattered instead of
    # all-reduced) — an offset bug in any flat buffer shows up here
    from repro.core.overlap import fsdp_unshard_full

    out["fsdp_grads_identical"] = trees_close(
        fsdp_unshard_full(gf, layout), grads_by_mode["two_phase"])

    # hierarchical (pod x data) reduction with int8-EF cross-pod compression:
    # wire bytes on the slow hop drop 4x vs fp32 / 2x vs bf16 (DESIGN §4)
    if devices >= 4:
        from repro.core.reduction import hierarchical_allreduce
        from repro.optim.compression import make_crosspod_codec

        mesh2 = make_mesh((2, devices // 2), ("pod", "data"))
        comp, decomp = make_crosspod_codec("pod")
        g0 = jax.random.normal(jax.random.PRNGKey(2), (1 << 16,))

        def plain(g):
            return jax.lax.psum(g, ("pod", "data"))

        def compressed(g):
            return hierarchical_allreduce(g, "data", "pod", scatter_dim=0,
                                          compress=comp, decompress=decomp)

        res = {}
        for name, fn in (("plain", plain), ("compressed", compressed)):
            f = jax.jit(jax.shard_map(
                fn, mesh=mesh2, in_specs=jax.sharding.PartitionSpec(),
                out_specs=jax.sharding.PartitionSpec(), check_vma=False))
            coll = parse_collectives(f.lower(g0).compile().as_text())
            ref = plain_ref(g0, mesh2)
            res[name] = {
                "wire_bytes": coll.total_wire_bytes,
                "crosspod_wire_bytes": sum(o.wire_bytes for o in coll.ops
                                           if o.group_size == 2),
                "rel_err": (float(jnp.max(jnp.abs(f(g0) - ref)))
                            / float(jnp.max(jnp.abs(ref)))
                            if name == "compressed" else 0.0),
            }
        out["crosspod_compression"] = res
    return out


def plain_ref(g, mesh2):
    import jax
    from jax.sharding import PartitionSpec as P

    return jax.jit(jax.shard_map(
        lambda g: jax.lax.psum(g, ("pod", "data")), mesh=mesh2,
        in_specs=P(), out_specs=P(), check_vma=False))(g)


def mem_worker(devices: int, steps: int) -> Dict[str, Any]:
    """Streaming ZeRO-3 memory probe: per-device peak LIVE parameter bytes,
    streaming vs gather-all, from the pre-optimization HLO live-interval
    model — each gathered buffer is live from its all-gather to its last
    compute consumer (the same spans AG-ADJACENCY lints; see
    analysis.rules.buckets.ag_live_spans), and peak live param bytes =
    persistent shard bytes + the largest simultaneous gathered set. The
    streaming peak must sit within shard + a 2-bucket working set; the
    gather-all peak carries every bucket at once. Losses are compared
    BIT-exactly across the two schedules — streaming moves WHEN buffers are
    gathered, never what is computed."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.hlo_ir import parse_hlo_module
    from repro.analysis.rules.base import LintContext
    from repro.analysis.rules.buckets import ag_live_spans
    from repro.config.base import ParallelConfig
    from repro.config.registry import get_arch
    from repro.core.overlap import fsdp_stream
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import (fsdp_init_state, fsdp_layout_for,
                                    make_fsdp_train_step)
    from repro.models.model import ModelOptions, build_model

    mesh = make_mesh((devices,), ("data",))
    cfg = get_arch("qwen3-8b").reduced()
    # matched options so both lowerings are numerically the same program:
    # unfused xent (the streamed loss uses the log_softmax path), full remat
    opts = ModelOptions(attn_impl="dense", scan_layers=False, remat="full",
                        fused_xent=False)
    model = build_model(cfg, opts)
    B, S = 2 * devices, 32
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    def peak_gathered_bytes(text: str) -> Dict[str, float]:
        module = parse_hlo_module(text)
        spans = ag_live_spans(module, LintContext())
        peak, count = 0.0, 0
        for comp, ag, start, _ in spans:
            live = sum(s.result_bytes() for c, s, b, e in spans
                       if c.name == comp.name and b <= start < e)
            if live > peak:
                peak, count = live, sum(
                    1 for c, _, b, e in spans
                    if c.name == comp.name and b <= start < e)
        return {"bytes": peak, "buffers": count, "n_ag": len(spans)}

    out: Dict[str, Any] = {"devices": devices, "arch": cfg.name,
                           "batch": B, "seq": S}
    losses = {}
    for name, par in {
        "streaming": ParallelConfig(param_shard=True, fsdp_streaming=True,
                                    scan_layers=False, remat="full"),
        "gather_all": ParallelConfig(param_shard=True, scan_layers=False,
                                     remat="full", bucket_order="layer"),
    }.items():
        layout, sync_axes = fsdp_layout_for(model, par, mesh)
        step = make_fsdp_train_step(model, par, mesh, layout=layout)
        jitted = jax.jit(step, donate_argnums=(0, 1))
        pflat, opt, _ = fsdp_init_state(model, par, mesh,
                                        jax.random.PRNGKey(0))
        text = (jitted.lower(pflat, opt, batch)
                .compiler_ir(dialect="hlo").as_hlo_text())
        peak = peak_gathered_bytes(text)
        shard = layout.shard_bytes()
        row = {"shard_bytes": shard,
               "peak_gathered_bytes": peak["bytes"],
               "peak_gathered_buffers": peak["buffers"],
               "all_gather_ops": peak["n_ag"],
               "peak_live_param_bytes": shard + peak["bytes"]}
        if par.fsdp_streaming:
            stream = fsdp_stream(layout, model.param_layers(), sync_axes)
            bucket = max(sum(g.padded * jnp.dtype(g.dtype).itemsize
                             for g in stream.groups_at(d))
                         for d in stream.depths)
            row["working_set_bound_bytes"] = (
                shard + par.fsdp_working_set * bucket)
            row["within_bound"] = (row["peak_live_param_bytes"]
                                   <= row["working_set_bound_bytes"])
        _, _, metrics = jitted(pflat, opt, batch)
        losses[name] = np.asarray(metrics["loss"]).tobytes()
        row["loss"] = float(metrics["loss"])
        out[name] = row
    out["loss_bit_equal"] = losses["streaming"] == losses["gather_all"]
    out["mem_saving_ratio"] = (out["gather_all"]["peak_live_param_bytes"]
                               / out["streaming"]["peak_live_param_bytes"])
    return out


def run_mem(sizes=(4,), steps: int = 1) -> Dict[str, Any]:
    from benchmarks._util import run_worker

    rows = [run_worker("benchmarks.lm_step", d, ["--mem", "--devices",
                                                 str(d)])
            for d in sizes]
    return {"table": "Streaming ZeRO-3 peak live param bytes "
                     "(streaming vs gather-all)", "rows": rows}


def moe_worker(devices: int, steps: int) -> Dict[str, Any]:
    import jax
    import numpy as np

    from benchmarks._util import timeit
    from repro.analysis.hlo import parse_collectives
    from repro.config.registry import get_arch
    from repro.launch.mesh import make_mesh
    from repro.models.model import ModelOptions, build_model
    from repro.sharding.rules import use_sharding

    # all devices on the 'model' axis -> one EP group: the regime where the
    # dispatch/combine all-to-alls dominate and the capacity chunking has
    # latency to hide. S chosen so C = ceil(S_loc*K/E * cf) stays divisible
    # by the chunk count on every bench topology (n=2 -> C=20, n=4 -> C=10).
    mesh = make_mesh((1, devices), ("data", "model"))
    cfg = get_arch("qwen3-moe-30b-a3b").reduced()
    B, S = 4, 64
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    out: Dict[str, Any] = {"devices": devices, "arch": cfg.name,
                           "batch": B, "seq": S}
    grads_by_mode = {}
    # two_phase = monolithic dispatch/combine (Q=1); hdot = capacity-chunked
    # double buffer (Q=2): the slice-k+1 dispatch is issued before the
    # slice-k expert FFN so the async scheduler can run them concurrently
    for mode, q in (("two_phase", 1), ("hdot", 2)):
        model = build_model(cfg, ModelOptions(attn_impl="dense",
                                              moe_a2a_chunks=q))
        with use_sharding(mesh):
            params = model.init(jax.random.PRNGKey(0))
            f = jax.jit(jax.value_and_grad(model.train_loss))
            sec = timeit(f, params, batch)
            loss, g = f(params, batch)
            hlo = f.lower(params, batch).compile().as_text()
        grads_by_mode[mode] = g
        coll = parse_collectives(hlo)
        out[mode] = {"seconds": sec, "steps_per_s": 1.0 / sec,
                     "loss": float(loss), "a2a_chunks": q,
                     "a2a_ops": coll.by_kind().get("all-to-all", (0, 0))[0],
                     "wire_bytes": coll.total_wire_bytes}

    # chunking must be a pure schedule change: same loss, same grads up to
    # the per-slice accumulation reordering the capacity reduction — a few
    # ulps AT THE LEAF'S OWN precision (expert grads are bf16, eps 2^-7)
    def leaf_close(x, y):
        import jax.numpy as jnp

        a = np.asarray(x, np.float32)
        b = np.asarray(y, np.float32)
        atol = 4 * float(jnp.finfo(x.dtype).eps) * (float(np.max(np.abs(a)))
                                                    + 1e-12)
        return np.allclose(a, b, rtol=0, atol=atol)

    out["grads_identical"] = bool(all(
        leaf_close(x, y)
        for x, y in zip(jax.tree.leaves(grads_by_mode["two_phase"]),
                        jax.tree.leaves(grads_by_mode["hdot"]))))
    return out


def run(sizes=(2, 8), steps: int = 3) -> Dict[str, Any]:
    from benchmarks._util import run_worker

    rows = [run_worker("benchmarks.lm_step", d, ["--devices", str(d)])
            for d in sizes]
    return {"table": "LM grad-sync schedules", "rows": rows}


def run_moe(sizes=(2, 4), steps: int = 3) -> Dict[str, Any]:
    from benchmarks._util import run_worker

    rows = [run_worker("benchmarks.lm_step", d,
                       ["--moe", "--devices", str(d)])
            for d in sizes]
    return {"table": "MoE EP a2a schedules (capacity-chunked vs monolithic)",
            "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--moe", action="store_true",
                    help="MoE EP a2a bench instead of the grad-sync bench")
    ap.add_argument("--mem", action="store_true",
                    help="streaming ZeRO-3 peak-live-bytes probe instead of "
                         "the grad-sync bench")
    ap.add_argument("--devices", type=int, default=2)
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()
    if args.worker:
        from benchmarks._util import emit

        emit(moe_worker(args.devices, args.steps) if args.moe
             else mem_worker(args.devices, args.steps) if args.mem
             else worker(args.devices, args.steps))
        return
    if args.mem:
        rec = run_mem()
        for r in rec["rows"]:
            print(f"devices={r['devices']} "
                  f"streaming peak {r['streaming']['peak_live_param_bytes']}"
                  f" B vs gather-all "
                  f"{r['gather_all']['peak_live_param_bytes']} B "
                  f"({r['mem_saving_ratio']:.2f}x, "
                  f"bit_equal={r['loss_bit_equal']})")
        return
    if args.moe:
        rec = run_moe()
        for r in rec["rows"]:
            print(f"devices={r['devices']} "
                  f"two_phase: {r['two_phase']['a2a_ops']} a2as, "
                  f"hdot: {r['hdot']['a2a_ops']} a2as, "
                  f"identical={r['grads_identical']}")
        return
    rec = run()
    for r in rec["rows"]:
        print(f"devices={r['devices']} "
              f"two_phase: {r['two_phase']['allreduce_ops']} ARs, "
              f"hdot: {r['hdot']['allreduce_ops']} ARs, "
              f"identical={r['grads_identical']}")


if __name__ == "__main__":
    main()
