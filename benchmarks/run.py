"""Benchmark driver: one module per paper table (DESIGN.md §7).

  table1_halo_memory  paper Table 1 (halo % of memory vs ranks)   exact match
  table2_heat2d       paper Tables 2-3 / Fig 4 (Heat2D schedules) measured
  table4_creams       paper Table 4 (CREAMS RK3 stencil)          measured
  hpccg               paper §4.3 / Fig 8 (taskified CG)           measured
  bench_overlap       Fig 1 concept (collective matmul ring)      measured
  lm_step             HDOT grad-sync buckets on an LM step        measured

Results land in results/bench/*.json + a markdown summary. Run:
  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (bench_overlap, hpccg, lm_step, table1_halo_memory,
                        table2_heat2d, table4_creams)
from benchmarks._util import RESULTS, save

SUITES = {
    "table1_halo_memory": lambda quick: table1_halo_memory.run(),
    "table2_heat2d": lambda quick: table2_heat2d.run(
        sizes=(1, 2) if quick else (1, 2, 4, 8),
        n=256 if quick else 1024, iters=10 if quick else 50),
    "table4_creams": lambda quick: table4_creams.run(
        sizes=(1, 2) if quick else (1, 2, 4, 8),
        nz=256 if quick else 1024, steps=4 if quick else 10),
    "hpccg": lambda quick: hpccg.run(
        sizes=(1, 2) if quick else (1, 2, 4, 8),
        n=24 if quick else 48, iters=10 if quick else 25),
    "bench_overlap": lambda quick: bench_overlap.run(
        sizes=(2,) if quick else (4, 8),
        s=1024 if quick else 4096, m=1024 if quick else 2048,
        n=1024 if quick else 2048),
    "lm_step": lambda quick: lm_step.run(sizes=(2,) if quick else (2, 8)),
}


def _summary_md(records: dict) -> str:
    lines = ["# Benchmark summary", ""]
    for name, rec in records.items():
        lines.append(f"## {name} — {rec.get('table', '')}")
        if "error" in rec:
            lines.append(f"**FAILED**: {rec['error']}")
            lines.append("")
            continue
        rows = rec.get("rows", [])
        if rows and "ranks" in rows[0]:
            lines.append("| ranks | halo % | paper % | match |")
            lines.append("|---|---|---|---|")
            for r in rows:
                lines.append(f"| {r['ranks']} | {r['halo_pct']} | "
                             f"{r['paper_pct']} | {r['match']} |")
        elif rows and "two_phase" in rows[0]:
            key = next(k for k in ("sweeps_per_s", "steps_per_s",
                                   "iters_per_s", "seconds")
                       if k in rows[0]["two_phase"])
            lines.append(f"| devices | two_phase {key} | hdot {key} | "
                         "hdot/two_phase |")
            lines.append("|---|---|---|---|")
            for r in rows:
                tp, hd = r["two_phase"][key], r["hdot"][key]
                ratio = (hd / tp) if key != "seconds" else (tp / hd)
                lines.append(f"| {r['devices']} | {tp:.2f} | {hd:.2f} | "
                             f"{ratio:.2f}x |")
        lines.append("")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small sizes / few devices (CI-sized)")
    args = ap.parse_args()

    todo = {args.only: SUITES[args.only]} if args.only else SUITES
    records = {}
    rc = 0
    for name, fn in todo.items():
        t0 = time.time()
        print(f"[bench] {name} ...", flush=True)
        try:
            rec = fn(args.quick)
            rec["elapsed_s"] = time.time() - t0
            save(name, rec)
            records[name] = rec
            print(f"[bench] {name} OK ({rec['elapsed_s']:.1f}s)")
        except Exception as e:
            records[name] = {"error": f"{type(e).__name__}: {e}"}
            traceback.print_exc()
            rc = 1
    RESULTS.mkdir(parents=True, exist_ok=True)
    md = _summary_md(records)
    (RESULTS / "summary.md").write_text(md)
    print(md)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
