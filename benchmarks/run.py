"""Benchmark driver: one module per paper table (DESIGN.md §7).

  table1_halo_memory  paper Table 1 (halo % of memory vs ranks)   exact match
  table2_heat2d       paper Tables 2-3 / Fig 4 (Heat2D schedules) measured
  table4_creams       paper Table 4 (CREAMS RK3 stencil)          measured
  hpccg               paper §4.3 / Fig 8 (taskified CG)           measured
  bench_overlap       Fig 1 concept (collective matmul ring)      measured
  lm_step             HDOT grad-sync buckets on an LM step        measured
  lm_moe              MoE EP capacity-chunked a2a vs monolithic   measured
  serve               continuous batching vs wave serving         measured
  rebalance           measured-cost dynamic re-cut straggler drill measured

Results land in results/bench/*.json + a markdown summary. Run:
  PYTHONPATH=src python -m benchmarks.run [--only NAME] [--quick]

--quick additionally writes BENCH_quick.json at the repo root: one
consolidated record (per suite: ops/s for both schedules + the
hdot/two_phase ratio, with `mesh_shape` rows tracking the N-D grid-mesh
decompositions — 2-D rows x cols and the 3-D planes x rows x cols HPCCG
mesh — and per-row jax_version/device_count provenance) that is COMMITTED,
so the overlap delta is a tracked trajectory across PRs instead of a
one-off print. Add --update-docs to regenerate the benchmark table in
docs/overlap.md from the same record (tests/test_docs.py fails if the
committed pair drifts apart).
"""
from __future__ import annotations

import argparse
import json
import time
import traceback

from benchmarks import (bench_overlap, hpccg, lm_step, rebalance, serve,
                        table1_halo_memory, table2_heat2d, table4_creams)
from benchmarks._util import REPO, RESULTS, save

SUITES = {
    "table1_halo_memory": lambda quick: table1_halo_memory.run(),
    "table2_heat2d": lambda quick: table2_heat2d.run(
        sizes=(1, 2) if quick else (1, 2, 4, 8),
        n=256 if quick else 1024, iters=10 if quick else 50,
        mesh_shapes=("4x1", "2x2") if quick else ("4x1", "2x2", "8x1", "4x2")),
    "table4_creams": lambda quick: table4_creams.run(
        sizes=(1, 2) if quick else (1, 2, 4, 8),
        nz=256 if quick else 1024, steps=4 if quick else 10,
        mesh_shapes=("2x2",) if quick else ("2x2", "4x2")),
    "hpccg": lambda quick: hpccg.run(
        sizes=(1, 2) if quick else (1, 2, 4, 8),
        n=24 if quick else 48, iters=10 if quick else 25,
        mesh_shapes=("4x1", "2x2", "2x2x2") if quick
        else ("4x1", "2x2", "8x1", "4x2", "2x2x2", "4x2x1")),
    "bench_overlap": lambda quick: bench_overlap.run(
        sizes=(2,) if quick else (4, 8),
        s=1024 if quick else 4096, m=1024 if quick else 2048,
        n=1024 if quick else 2048),
    "lm_step": lambda quick: lm_step.run(sizes=(2,) if quick else (2, 8)),
    "lm_moe": lambda quick: lm_step.run_moe(sizes=(2,) if quick else (2, 4)),
    "fsdp_mem": lambda quick: lm_step.run_mem(sizes=(4,)),
    "serve": lambda quick: serve.run(quick=quick),
    "rebalance": lambda quick: rebalance.run(
        configs=((4, 3.0),) if quick else ((4, 3.0), (4, 5.0), (8, 3.0)),
        steps=20 if quick else 32),
}


# suite -> short key in the consolidated BENCH_quick.json record
QUICK_KEYS = {"table2_heat2d": "heat2d", "table4_creams": "creams",
              "hpccg": "hpccg", "bench_overlap": "overlap",
              "lm_step": "lm_step", "lm_moe": "moe", "serve": "serve",
              "rebalance": "rebalance", "fsdp_mem": "fsdp_mem"}


def _schedule_rates(row: dict):
    """(metric, two_phase_rate, hdot_rate) for a schedule-comparison row, or
    None. Single source of truth for the per-suite perf key — 'seconds' rows
    are inverted to a rate so bigger is always better."""
    if "two_phase" not in row:
        return None
    key = next((k for k in ("sweeps_per_s", "steps_per_s", "iters_per_s",
                            "tokens_per_s")
                if k in row["two_phase"]), None)
    if key is not None:
        return key, row["two_phase"][key], row["hdot"][key]
    return ("ops_per_s", 1.0 / row["two_phase"]["seconds"],
            1.0 / row["hdot"]["seconds"])


def _quick_record(records: dict) -> dict:
    """Consolidate per-suite results into {suite: rows + summary ratio}.
    The summary ratio is taken from the largest-device row (where the
    schedules diverge most)."""
    out: dict = {}
    for name, short in QUICK_KEYS.items():
        rec = records.get(name)
        if rec is None:
            continue
        if "error" in rec:
            out[short] = {"error": rec["error"]}
            continue
        rows = []
        for r in rec.get("rows", []):
            if "streaming" in r:   # fsdp_mem peak-live-bytes probe row
                row = {"devices": r.get("devices"),
                       "metric": "peak_live_param_bytes",
                       "streaming": r["streaming"]["peak_live_param_bytes"],
                       "gather_all": r["gather_all"]["peak_live_param_bytes"],
                       "shard_bytes": r["streaming"]["shard_bytes"],
                       "within_working_set_bound":
                           r["streaming"].get("within_bound"),
                       "loss_bit_equal": r.get("loss_bit_equal"),
                       "mem_saving_ratio": r["mem_saving_ratio"]}
                for k in ("jax_version", "device_count"):
                    if k in r:
                        row[k] = r[k]
                rows.append(row)
                continue
            rates = _schedule_rates(r)
            if rates is None:
                continue
            key, tp, hd = rates
            row = {"devices": r.get("devices"), "metric": key,
                   "two_phase": tp, "hdot": hd,
                   "hdot_two_phase_ratio": hd / tp}
            if "fsdp" in r:   # ZeRO-3 composition of the bucketed schedule
                fs = (r["fsdp"][key] if key in r["fsdp"]
                      else 1.0 / r["fsdp"]["seconds"])
                row["fsdp"] = fs
                row["fsdp_two_phase_ratio"] = fs / tp
            # runner provenance (stamped by _util.emit in every worker):
            # artifacts from different CI runners are only comparable when
            # the toolchain + device count travel with the row
            for k in ("jax_version", "device_count"):
                if k in r:
                    row[k] = r[k]
            if "mesh_shape" in r:     # N-D grid-mesh decomposition row
                row["mesh_shape"] = r["mesh_shape"]
            rows.append(row)
        entry: dict = {"rows": rows}
        # headline stays the largest 1-D row (comparable across PRs, PR 2
        # onward); 2-D / 3-D mesh rows get their own headline so each
        # topology gap is tracked without redefining the original trajectory
        slab = [r for r in rows
                if "mesh_shape" not in r and "hdot_two_phase_ratio" in r]
        mesh2 = [r for r in rows if r.get("mesh_shape", "").count("x") == 1]
        mesh3 = [r for r in rows if r.get("mesh_shape", "").count("x") == 2]
        if slab:
            entry["hdot_two_phase_ratio"] = slab[-1]["hdot_two_phase_ratio"]
        if mesh2:
            entry["hdot_two_phase_ratio_2d"] = mesh2[-1]["hdot_two_phase_ratio"]
        if mesh3:
            entry["hdot_two_phase_ratio_3d"] = mesh3[-1]["hdot_two_phase_ratio"]
        fsdp = [r for r in rows if "fsdp_two_phase_ratio" in r]
        if fsdp:   # lm_step ZeRO-3 headline, gated like the others
            entry["fsdp_two_phase_ratio"] = fsdp[-1]["fsdp_two_phase_ratio"]
        mem = [r for r in rows if "mem_saving_ratio" in r]
        if mem:    # streaming ZeRO-3 memory headline (ci_gate: must be > 1)
            entry["mem_saving_ratio"] = mem[-1]["mem_saving_ratio"]
        out[short] = entry
    return out


def _summary_md(records: dict) -> str:
    lines = ["# Benchmark summary", ""]
    for name, rec in records.items():
        lines.append(f"## {name} — {rec.get('table', '')}")
        if "error" in rec:
            lines.append(f"**FAILED**: {rec['error']}")
            lines.append("")
            continue
        rows = rec.get("rows", [])
        if rows and "ranks" in rows[0]:
            lines.append("| ranks | halo % | paper % | match |")
            lines.append("|---|---|---|---|")
            for r in rows:
                lines.append(f"| {r['ranks']} | {r['halo_pct']} | "
                             f"{r['paper_pct']} | {r['match']} |")
        elif rows and "streaming" in rows[0]:
            lines.append("| devices | streaming peak bytes | "
                         "gather-all peak bytes | saving | bit-equal |")
            lines.append("|---|---|---|---|---|")
            for r in rows:
                lines.append(
                    f"| {r['devices']} | "
                    f"{r['streaming']['peak_live_param_bytes']} | "
                    f"{r['gather_all']['peak_live_param_bytes']} | "
                    f"{r['mem_saving_ratio']:.2f}x | "
                    f"{r['loss_bit_equal']} |")
        elif rows and "two_phase" in rows[0]:
            key = _schedule_rates(rows[0])[0]
            lines.append(f"| devices | two_phase {key} | hdot {key} | "
                         "hdot/two_phase |")
            lines.append("|---|---|---|---|")
            for r in rows:
                _, tp, hd = _schedule_rates(r)
                lines.append(f"| {r['devices']} | {tp:.2f} | {hd:.2f} | "
                             f"{hd / tp:.2f}x |")
        lines.append("")
    return "\n".join(lines)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", choices=sorted(SUITES), default=None)
    ap.add_argument("--quick", action="store_true",
                    help="small sizes / few devices (CI-sized)")
    ap.add_argument("--update-docs", action="store_true",
                    help="regenerate the benchmark table in docs/overlap.md "
                         "from this run's BENCH_quick.json (requires --quick "
                         "without --only)")
    args = ap.parse_args()
    if args.update_docs and (not args.quick or args.only):
        ap.error("--update-docs needs --quick and no --only")

    todo = {args.only: SUITES[args.only]} if args.only else SUITES
    records = {}
    rc = 0
    for name, fn in todo.items():
        t0 = time.time()
        print(f"[bench] {name} ...", flush=True)
        try:
            rec = fn(args.quick)
            rec["elapsed_s"] = time.time() - t0
            save(name, rec)
            records[name] = rec
            print(f"[bench] {name} OK ({rec['elapsed_s']:.1f}s)")
        except Exception as e:
            records[name] = {"error": f"{type(e).__name__}: {e}"}
            traceback.print_exc()
            rc = 1
    RESULTS.mkdir(parents=True, exist_ok=True)
    md = _summary_md(records)
    (RESULTS / "summary.md").write_text(md)
    print(md)
    if args.quick and not args.only:
        quick = _quick_record(records)
        path = REPO / "BENCH_quick.json"
        path.write_text(json.dumps(quick, indent=1) + "\n")
        print(f"[bench] wrote {path}")
        if args.update_docs:
            from benchmarks import docs_sync

            changed = docs_sync.update_docs(quick)
            print(f"[bench] docs/overlap.md table "
                  f"{'updated' if changed else 'already in sync'}")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
