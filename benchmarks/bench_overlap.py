"""Figure 1 concept μbench: two-phase vs HDOT (ring) collective matmul.

ag_matmul: the two-phase schedule is all_gather(x) then one big matmul; the
HDOT schedule is P chunk-matmuls riding a ppermute ring (core.collective_matmul).
We verify numerics, count collectives, and report wall clock on N virtual
devices. On CPU the ring adds launch overhead (no async ICI to hide into) —
the structural metric (P small ppermutes interleaved with P chunk matmuls vs
1 gather before 1 matmul) is the reproduction; the TPU win is the roofline
overlap bound reported alongside.
"""
from __future__ import annotations

import argparse
from typing import Any, Dict


def worker(devices: int, s: int, m: int, n: int) -> Dict[str, Any]:
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from benchmarks._util import timeit
    from repro.analysis.hlo import parse_collectives
    from repro.core.collective_matmul import ag_matmul
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((devices,), ("model",))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (s, m), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (m, n), jnp.bfloat16)

    out: Dict[str, Any] = {"devices": devices, "s": s, "m": m, "n": n}
    ys = {}
    for mode in ("two_phase", "hdot"):
        f = jax.jit(jax.shard_map(
            functools.partial(ag_matmul, axis_name="model", mode=mode),
            mesh=mesh, in_specs=(P("model", None), P(None, "model")),
            out_specs=P(None, "model")))
        sec = timeit(f, x, w)
        ys[mode] = np.asarray(f(x, w), np.float32)
        coll = parse_collectives(f.lower(x, w).compile().as_text())
        out[mode] = {"seconds": sec,
                     "coll_ops": len(coll.ops),
                     "coll_by_kind": {k: v[0] for k, v in coll.by_kind().items()},
                     "wire_bytes": coll.total_wire_bytes}
    out["numerics_close"] = bool(np.allclose(ys["two_phase"], ys["hdot"],
                                             rtol=2e-2, atol=2e-2))
    # roofline overlap bound (TPU constants): flops of the matmul vs wire time
    flops = 2.0 * s * m * n / devices
    t_comp = flops / 197e12
    t_coll = out["two_phase"]["wire_bytes"] / 50e9
    out["roofline"] = {
        "t_comp_s": t_comp, "t_coll_s": t_coll,
        "two_phase_bound_s": t_comp + t_coll,
        "hdot_bound_s": max(t_comp, t_coll),
        "predicted_speedup": (t_comp + t_coll) / max(t_comp, t_coll),
    }
    return out


def run(sizes=(4, 8), s: int = 4096, m: int = 2048, n: int = 2048
        ) -> Dict[str, Any]:
    from benchmarks._util import run_worker

    rows = [run_worker("benchmarks.bench_overlap", d,
                       ["--devices", str(d), "--s", str(s), "--m", str(m),
                        "--n", str(n)])
            for d in sizes]
    return {"table": "overlap μbench (collective matmul)", "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--s", type=int, default=4096)
    ap.add_argument("--m", type=int, default=2048)
    ap.add_argument("--n", type=int, default=2048)
    args = ap.parse_args()
    if args.worker:
        from benchmarks._util import emit

        emit(worker(args.devices, args.s, args.m, args.n))
        return
    rec = run()
    for r in rec["rows"]:
        rf = r["roofline"]
        print(f"devices={r['devices']} "
              f"two_phase={r['two_phase']['coll_ops']} colls, "
              f"hdot={r['hdot']['coll_ops']} colls, close={r['numerics_close']}, "
              f"predicted TPU speedup={rf['predicted_speedup']:.2f}x")


if __name__ == "__main__":
    main()
