"""Serving bench: continuous batching vs wave scheduling on one trace.

Both schedulers share the BatchServer cache layout and the same seeded
Poisson arrival trace at saturating load (arrivals far faster than service,
so the queue never starves and the comparison is pure scheduling). Requests
carry a skewed max_new mix (short and long interleaved): the wave scheduler
pays max(max_new) decode steps for every request in a wave, while the
continuous scheduler re-admits into a slot the step it frees — the
structural margin the bench asserts (`continuous strictly more tokens/s`).

Rows reuse the repo-wide two-schedule record shape — `two_phase` = wave
(serial phases: batch, then decode to the slowest member), `hdot` =
continuous (admission rides along with decode) — so run.py's quick record
and ci_gate.py gate the continuous/wave ratio exactly like the overlap
suites. Latency is measured per token from Poisson arrival to the server's
`Request.finish` stamp (p50/p99 across requests).
"""
from __future__ import annotations

import argparse
import time
from typing import Any, Dict, List


def _trace(n: int, rate: float, plen: int, seed: int):
    """Seeded Poisson arrivals + fixed-width prompts + skewed max_new mix.
    Returns (arrive_s, prompts, max_new); identical for both schedulers."""
    import numpy as np

    rng = np.random.default_rng(seed)
    arrive = np.cumsum(rng.exponential(1.0 / rate, n))
    prompts = rng.integers(1, 1000, size=(n, plen)).tolist()
    max_new = [2 if i % 2 == 0 else 24 for i in range(n)]
    return arrive, prompts, max_new


def _requests(prompts, max_new) -> List[Any]:
    from repro.runtime.server import Request

    return [Request(prompt=list(p), max_new_tokens=m)
            for p, m in zip(prompts, max_new)]


def _latencies_ms(reqs, t0, arrive) -> List[float]:
    """Per-token latency (ms) of each request: Poisson arrival -> finish."""
    return [(r.finish - (t0 + a)) * 1e3 / len(r.output)
            for r, a in zip(reqs, arrive)]


def _serve_wave(srv, reqs, arrive) -> float:
    """Replay the trace under wave scheduling. Waves are gated on a full
    batch (or the drained tail) so every wave keeps the compiled b=slots
    shape — the strongest (recompile-free) version of the wave baseline."""
    t0 = time.monotonic()
    i = 0
    while i < len(reqs) or srv.queue:
        now = time.monotonic() - t0
        while i < len(reqs) and arrive[i] <= now:
            srv.submit(reqs[i])
            i += 1
        if len(srv.queue) >= srv.slots or (i == len(reqs) and srv.queue):
            srv.run_wave()
        else:
            time.sleep(2e-4)
    return t0


def _serve_continuous(srv, reqs, arrive) -> float:
    t0 = time.monotonic()
    state = {"i": 0}

    def poll():
        now = time.monotonic() - t0
        while state["i"] < len(reqs) and arrive[state["i"]] <= now:
            srv.submit(reqs[state["i"]])
            state["i"] += 1
        return state["i"] < len(reqs)

    srv.run_continuous(poll)
    return t0


def worker(devices: int, requests: int, slots: int, rate: float,
           seed: int) -> Dict[str, Any]:
    import dataclasses

    import numpy as np

    from repro.config.registry import get_arch
    from repro.models.model import ModelOptions, build_model, init_params
    from repro.runtime.server import BatchServer

    cfg = dataclasses.replace(get_arch("internlm2-1.8b").reduced(),
                              num_layers=2)
    model = build_model(cfg, ModelOptions(attn_impl="dense"))
    params = init_params(cfg, seed=0)
    plen, max_len = 4, 32
    arrive, prompts, max_new = _trace(requests, rate, plen, seed)

    out: Dict[str, Any] = {"devices": devices, "arch": cfg.name,
                           "slots": slots, "requests": requests,
                           "offered_req_per_s": rate, "seed": seed}
    runners = {"two_phase": ("wave", _serve_wave),
               "hdot": ("continuous", _serve_continuous)}
    for key, (name, serve) in runners.items():
        srv = BatchServer(model, params, slots=slots, max_len=max_len)
        # warmup: one full batch through the scheduler pays every jit
        # compile (prefill/admit at the trace's fixed plen + decode step)
        warm = _requests(prompts[:slots], [2] * slots)
        serve(srv, warm, np.zeros(slots))
        steps0 = srv.stats["decode_steps"]

        reqs = _requests(prompts, max_new)
        t0 = serve(srv, reqs, arrive)
        seconds = time.monotonic() - t0
        assert all(r.output is not None and r.finish is not None
                   for r in reqs)
        toks = sum(len(r.output) for r in reqs)
        lat = _latencies_ms(reqs, t0, arrive)
        out[key] = {"scheduler": name, "seconds": seconds,
                    "tokens_per_s": toks / seconds, "tokens": toks,
                    "decode_steps": srv.stats["decode_steps"] - steps0,
                    "p50_ms_per_token": float(np.percentile(lat, 50)),
                    "p99_ms_per_token": float(np.percentile(lat, 99))}

    # the acceptance bar: at saturating load the continuous scheduler must
    # strictly beat the wave scheduler on delivered tokens/s
    assert out["hdot"]["tokens_per_s"] > out["two_phase"]["tokens_per_s"], out
    return out


def run(quick: bool = True) -> Dict[str, Any]:
    from benchmarks._util import run_worker

    n = 16 if quick else 48
    rows = [run_worker("benchmarks.serve", 1,
                       ["--requests", str(n), "--slots", "4",
                        "--rate", "200.0", "--seed", "0"])]
    return {"table": "Serving schedulers (continuous vs wave)", "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=200.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.worker:
        from benchmarks._util import emit

        emit(worker(args.devices, args.requests, args.slots, args.rate,
                    args.seed))
        return
    rec = run()
    for r in rec["rows"]:
        print(f"slots={r['slots']} requests={r['requests']} "
              f"wave: {r['two_phase']['tokens_per_s']:.1f} tok/s "
              f"(p99 {r['two_phase']['p99_ms_per_token']:.1f} ms/tok), "
              f"continuous: {r['hdot']['tokens_per_s']:.1f} tok/s "
              f"(p99 {r['hdot']['p99_ms_per_token']:.1f} ms/tok)")


if __name__ == "__main__":
    main()
